#!/usr/bin/env bash
# Single CI entry point.
#
#   scripts/ci.sh            # tier-1: the full test suite (fail-fast)
#   scripts/ci.sh kernels    # fast kernel-parity subset only (~1 min)
#   scripts/ci.sh docs       # broken md links / stale README references
#   scripts/ci.sh all        # tier-1, then kernels, then docs
#
# Tier-1 is the gate every PR must keep green (ROADMAP.md).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier1() {
    python -m pytest -x -q
}

# Fast parity subset: every Pallas kernel against its ref.py oracle
# (interpret mode on CPU) + the fused_kernel == fused model-level check.
kernels() {
    python -m pytest -q \
        tests/test_kernels.py \
        tests/test_wkv6_kernel.py \
        "tests/test_moe.py::test_resmoe_fused_kernel_matches_fused"
}

# Docs tier: intra-repo markdown links must resolve and README code blocks
# must reference real modules/paths/flags (no jax import — runs in ~1 s).
docs() {
    python scripts/check_docs.py
}

case "${1:-tier1}" in
    tier1)   tier1 ;;
    kernels) kernels ;;
    docs)    docs ;;
    all)     tier1; kernels; docs ;;
    *) echo "usage: $0 [tier1|kernels|docs|all]" >&2; exit 2 ;;
esac
