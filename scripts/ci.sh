#!/usr/bin/env bash
# Single CI entry point (mirrored by .github/workflows/ci.yml as a matrix).
#
#   scripts/ci.sh            # tier-1: the full test suite (fail-fast)
#   scripts/ci.sh kernels    # fast kernel-parity subset only (~1 min)
#   scripts/ci.sh multidev   # expert-parallel / sharding tests on 8 forced
#                            # host devices (the EP path, exercised, not
#                            # just importable)
#   scripts/ci.sh bench      # benchmark smoke: `benchmarks.run --fast`
#                            # must exit 0 and write BENCH_<n>.json (the
#                            # per-PR perf-trajectory artifact)
#   scripts/ci.sh soak       # seeded long-run serving churn: hundreds of
#                            # requests through a tiny page pool (forced
#                            # preemption/reuse); excluded from tier-1 by
#                            # the `-m "not soak"` addopts default
#   scripts/ci.sh zoo        # architecture-matrix serving differentials:
#                            # every mixer kind (gqa/mla/rglru/rwkv, hybrid,
#                            # compressed-MoE) through ContinuousServer vs
#                            # the sync oracle with forced preemption
#   scripts/ci.sh spec       # barycenter-draft speculative decoding
#                            # differential matrix: spec_k > 0 must be
#                            # token-identical to plain decode across both
#                            # restore-free verifier paths, both store
#                            # dtypes, forced preemption mid-speculation
#                            # and page-boundary rejections
#   scripts/ci.sh engine     # overlapped-engine differentials:
#                            # OverlappedServer token-identical to the
#                            # sync oracle across dense/MoE/recurrent/
#                            # hybrid stacks, forced preemption and
#                            # spec_k in {0, 2} included
#   scripts/ci.sh compress   # compressed-store persist/boot roundtrips:
#                            # heterogeneous (per-layer plan) stores booted
#                            # from disk == in-memory through both paged
#                            # servers (preemption, spec_k in {0, 2}), plus
#                            # CLI subprocess roundtrips for fp32 / int8 /
#                            # --plan / --byte-budget stores
#   scripts/ci.sh multiproc  # multi-host routed serving, CPU-simulated:
#                            # two repro.launch.router worker processes
#                            # under one jax.distributed coordinator, the
#                            # routed union diffed token-for-token against
#                            # an in-process oracle (forced preemption
#                            # included)
#   scripts/ci.sh docs       # broken md links / stale README references /
#                            # serve CLI flag coverage in docs/SERVING.md /
#                            # apply-mode x store-dtype parity-test matrix
#   scripts/ci.sh all        # every tier above, tier-1 first
#
# Tier-1 is the gate every PR must keep green (ROADMAP.md).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier1() {
    python -m pytest -x -q
}

# Fast parity subset: every Pallas kernel against its ref.py oracle
# (interpret mode on CPU) + the kernel == einsum model-level checks.
kernels() {
    python -m pytest -q \
        tests/test_kernels.py \
        tests/test_wkv6_kernel.py \
        tests/test_moe_token.py \
        "tests/test_quant.py::test_grouped_q8_kernel_matches_dequant_ref" \
        "tests/test_quant.py::test_token_q8_kernel_matches_dequant_ref" \
        "tests/test_moe.py::test_resmoe_fused_kernel_matches_fused"
}

# Expert-parallel tier: the tests fork their own 8-device subprocesses,
# but we ALSO force 8 host devices in the parent so any in-process mesh
# helper sees a real multi-device topology on a bare CPU runner.
multidev() {
    XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
        python -m pytest -q tests/test_moe_ep.py tests/test_sharding.py \
        "tests/test_quant.py::test_ep_int8_parity_forced_mesh"
}

# Bench smoke tier: the fast benchmark pass must complete (nonzero exit on
# any suite failure — benchmarks/run.py propagates) and leave a
# machine-readable BENCH_<n>.json (n = commit count) so the perf
# trajectory accumulates per PR; the workflow uploads it as an artifact.
bench() {
    local n
    n="$(git rev-list --count HEAD 2>/dev/null || echo 0)"
    python -m benchmarks.run --fast --json "BENCH_${n}.json"
    test -s "BENCH_${n}.json"
    # the quantized-store rows (grouped/token int8 comparisons + the
    # factor-bytes roofline) must land in the trajectory artifact
    python - "BENCH_${n}.json" <<'PY'
import json, sys
rows = json.load(open(sys.argv[1]))
quant = [k for k in rows if "int8" in k or "/quant" in k]
assert any("quant_roofline" in k for k in quant), \
    f"no quant roofline rows in bench artifact ({len(rows)} rows)"
assert any("int8" in k for k in quant), \
    f"no int8 comparison rows in bench artifact ({len(rows)} rows)"
# the speculative-decoding comparison (accepted-tokens/step + tokens/s per
# spec_k) must land too — the suite itself asserts the >1 acceptance floor
spec = [k for k in rows if k.startswith("SERVE/spec/")]
assert any("accepted_tok_per_step" in k for k in spec), \
    f"no spec acceptance rows in bench artifact ({len(rows)} rows)"
# the store-bytes/quality frontier (benchmarks/frontier.py) must land:
# the uniform curve plus the budget plan that Pareto-dominates it
front = [k for k in rows if k.startswith("FRONTIER/")]
assert any("dominates" in k for k in front), \
    f"no frontier dominance row in bench artifact ({len(rows)} rows)"
assert sum("uniform" in k for k in front) >= 4, \
    f"frontier uniform curve too sparse ({len(front)} rows)"
# routed-serving scaling (aggregate tokens/s vs replica count) must land
router = [k for k in rows if k.startswith("SERVE/router/")]
assert sum(k.endswith("_tok_per_s") for k in router) >= 2, \
    f"no router replica-scaling rows in bench artifact ({len(rows)} rows)"
assert any("scaling_x" in k for k in router), \
    f"no router scaling summary row in bench artifact ({len(rows)} rows)"
# every row must carry its metric as a NUMBER in `value` (provenance
# strings belong in `derived`) — the trajectory tooling plots `value`
bad = [k for k, v in rows.items()
       if not isinstance(v.get("value"), (int, float))
       or isinstance(v.get("value"), bool)]
assert not bad, f"rows without numeric value: {bad[:5]} (+{len(bad)} total)"
print(f"bench artifact OK: {len(quant)} quantized rows, "
      f"{len(spec)} spec rows, {len(front)} frontier rows, "
      f"{len(router)} router rows of {len(rows)}")
PY
}

# Soak tier: the continuous-batching server under sustained churn — the
# @pytest.mark.soak tests stream hundreds of small requests through a page
# pool far below num_slots * max_seq, so every step exercises preemption,
# re-admission-by-recompute, and page reuse, with the sync Server as the
# token-level oracle on a deterministic subset. The CLI `-m soak`
# overrides the pyproject addopts default that keeps tier-1 fast.
soak() {
    python -m pytest -q -m soak tests/test_serve.py
}

# Zoo tier: ContinuousServer == Server token parity on every architecture
# family in the model zoo (pure attention, sliding local/global, MLA+MoE,
# pure recurrent, hybrid, compressed-MoE hybrid), each with at least one
# forced preemption-restore. check_parity_matrix.py requires a
# `# PARITY: mixer/<kind>` marker per MIXER_KINDS entry, so a new mixer
# cannot ship without a row here.
zoo() {
    python -m pytest -q -m zoo tests/
}

# Spec tier: speculative decoding as a pure latency knob — every spec_k>0
# parametrization of the differential suites (launch/spec.py drafter +
# rollback against the plain-decode oracle). check_parity_matrix.py
# requires a `# PARITY: spec/<mode>-<dtype>` marker per SPEC_PARITY_MODES
# x STORE_DTYPES cell, so a new verifier path cannot ship uncovered.
spec() {
    python -m pytest -q -m spec tests/
}

# Engine tier: the overlapped serving engine (launch/engine.py) against
# the sync oracle — randomized schedules, forced preemption-restore,
# EOS-mid-decode (the zombie path), spec_k in {0, 2} — across the same
# architecture spread as the zoo tier. Fast engine unit tests (stats
# schema, warmup no-recompile, refusals) stay in tier-1 unmarked.
engine() {
    python -m pytest -q -m engine tests/
}

# Compress tier: the store persistence/boot matrix (tests/
# test_plan_serving.py) — disk-booted trimmed + mixed-rank + mixed-dtype
# stores must serve token-identically to the in-memory tree through
# ContinuousServer AND OverlappedServer (forced preemption, spec_k 0/2),
# and the four CLI flows (uniform fp32, uniform int8, --plan,
# --byte-budget) roundtrip as subprocesses with diffed outputs.
compress() {
    python -m pytest -q -m compress tests/
}

# Multiproc tier: the multi-host topology without multiple hosts — each
# @pytest.mark.multiproc test launches two `repro.launch.router` worker
# subprocesses that join one jax.distributed coordinator (CPU-simulated
# host devices), serve their deterministic share of a seeded trace, and
# write their outputs to JSON; the parent diffs the union against the
# sync oracle. Pins the bring-up path (init_distributed, process-indexed
# assignment) that no in-process test can reach.
multiproc() {
    python -m pytest -q -m multiproc tests/test_multiproc.py
}

# Docs tier: intra-repo markdown links must resolve, README code blocks
# must reference real modules/paths/flags, the serve CLI must be fully
# documented in docs/SERVING.md, and every (apply_mode, store_dtype)
# combination must declare a parity test (no jax import — runs in ~1 s).
docs() {
    python scripts/check_docs.py
    python scripts/check_parity_matrix.py
}

case "${1:-tier1}" in
    tier1)    tier1 ;;
    kernels)  kernels ;;
    multidev) multidev ;;
    bench)    bench ;;
    soak)     soak ;;
    zoo)      zoo ;;
    spec)     spec ;;
    engine)   engine ;;
    compress) compress ;;
    multiproc) multiproc ;;
    docs)     docs ;;
    all)      tier1; kernels; multidev; bench; soak; zoo; spec; engine; compress; multiproc; docs ;;
    *) echo "usage: $0 [tier1|kernels|multidev|bench|soak|zoo|spec|engine|compress|multiproc|docs|all]" >&2; exit 2 ;;
esac
