"""Perf-iteration harness: lower one cell with variations, print roofline terms.

Used for the hypothesis -> change -> measure -> validate loop (§Perf).

    PYTHONPATH=src python scripts/perf_iter.py --arch llama3-405b \
        --shape train_4k [--microbatches 8] [--override seq=model] \
        [--apply-mode fused_shared] [--tag baseline]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--apply-mode", default=None)
    ap.add_argument("--compressed", action="store_true",
                    help="lower with the ResMoE-SVD compressed store")
    ap.add_argument("--override", action="append", default=[],
                    help="logical=meshaxis (e.g. cache_seq=model, heads=)")
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--out", default="perf_iters")
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell
    from repro.launch.hlo_cost import analyze_hlo_text
    from repro.launch.mesh import make_production_mesh
    from benchmarks.roofline.analyze import model_flops
    from benchmarks.roofline.hw import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        overrides[k] = (None if v in ("", "none", "None") else
                        tuple(v.split("+")) if "+" in v else v)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    t0 = time.time()
    lowered, meta = lower_cell(
        args.arch, args.shape, mesh,
        microbatches=args.microbatches,
        sharding_overrides=overrides or None,
        apply_mode=args.apply_mode,
        compressed=args.compressed,
    )
    compiled = lowered.compile()
    t1 = time.time()
    cost = analyze_hlo_text(compiled.as_text())
    try:
        mem = compiled.memory_analysis()
        temp = int(mem.temp_size_in_bytes)
        arg = int(mem.argument_size_in_bytes)
    except Exception:
        temp = arg = 0

    chips = 512 if args.multi_pod else 256
    mf = model_flops(args.arch, args.shape) / chips
    terms = {
        "compute_s": cost["flops"] / PEAK_FLOPS_BF16,
        "memory_s": cost["bytes"] / HBM_BW,
        "collective_s": cost["coll_total"] / ICI_BW_PER_LINK,
    }
    dominant = max(terms, key=terms.get)
    rec = {
        "tag": args.tag, "arch": args.arch, "shape": args.shape,
        "meta": meta, "overrides": overrides,
        "flops_dev": cost["flops"], "bytes_dev": cost["bytes"],
        "coll_dev": cost["coll_total"],
        "coll_detail": {k: v for k, v in cost.items()
                        if k.startswith("coll_") and v and k != "coll_total"},
        **terms,
        "dominant": dominant,
        "model_flops_dev": mf,
        "useful_ratio": mf / cost["flops"] if cost["flops"] else None,
        "roofline_frac": (mf / PEAK_FLOPS_BF16) / max(terms.values()),
        "hbm_temp_gb": temp / 2**30,
        "hbm_args_gb": arg / 2**30,
        "compile_s": round(t1 - t0, 1),
    }
    os.makedirs(args.out, exist_ok=True)
    fname = f"{args.arch}__{args.shape}__{args.tag}.json"
    with open(os.path.join(args.out, fname), "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: (round(v, 6) if isinstance(v, float) else v)
                      for k, v in rec.items() if k != "coll_detail"}, indent=1))
    print("coll_detail:", {k: f"{v:.3e}" for k, v in rec["coll_detail"].items()})


if __name__ == "__main__":
    main()
