#!/usr/bin/env python
"""Docs CI tier: fail on broken intra-repo references (scripts/ci.sh docs).

Two checks, both purely static (no jax import):

1. every relative markdown link ``[text](path)`` in every tracked ``*.md``
   must resolve to an existing file (anchors stripped; http/mailto/#
   links skipped). SNIPPETS.md is exempt — it quotes external repos.

2. code blocks in the front-door READMEs (README.md, benchmarks/README.md)
   must reference things that exist:
     * path-like tokens (``scripts/ci.sh``, ``examples/*.py``) must exist;
     * module tokens (``repro.launch.serve``, ``benchmarks.run``) must
       resolve to a source file or package under src/ or the repo root;
     * ``--flags`` on a line that invokes a resolvable script/module must
       appear verbatim in that script's source (argparse strings).

3. the serve CLI is fully documented: every ``add_argument("--flag")``
   in src/repro/launch/serve.py must appear (backticked) in
   docs/SERVING.md — the operator guide cannot silently fall behind
   the CLI.

4. the store-family flags (persistence + per-layer compression plans:
   --store-dir, --store-dtype, --plan, --byte-budget) must ALSO appear
   (backticked) in docs/STORES.md — the store reference documents every
   flag that shapes the on-disk artifact.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```[\w-]*\n(.*?)```", re.S)
PATH_RE = re.compile(r"(?<![\w./-])((?:[\w.-]+/)+[\w.-]+\.(?:py|sh|md|txt|toml))")
MODULE_RE = re.compile(r"(?<![\w.])((?:repro|benchmarks)(?:\.\w+)+)")
# standalone flags only: `--flag value`; assignments like FOO=--bar=8 are
# environment plumbing, not argparse flags of the invoked script
FLAG_RE = re.compile(r"(?<=\s)(--[a-z][\w-]*)(?=\s|$)")

EXEMPT_LINKS = {"SNIPPETS.md"}
CODE_CHECKED = ("README.md", "benchmarks/README.md")

SERVE_CLI = Path("src/repro/launch/serve.py")
SERVING_DOC = Path("docs/SERVING.md")
STORES_DOC = Path("docs/STORES.md")
STORE_FLAGS = ("--store-dir", "--store-dtype", "--plan", "--byte-budget")
ADD_ARG_RE = re.compile(r"add_argument\(\s*\"(--[\w-]+)\"")


def md_files():
    for p in sorted(ROOT.rglob("*.md")):
        if any(part.startswith(".") for part in p.relative_to(ROOT).parts):
            continue
        yield p


def check_links(errors):
    for md in md_files():
        if md.name in EXEMPT_LINKS:
            continue
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).exists() and not (ROOT / path).exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")


def resolve_module(mod: str):
    rel = Path(*mod.split("."))
    for base in (ROOT / "src", ROOT):
        for cand in (base / rel.with_suffix(".py"), base / rel / "__init__.py"):
            if cand.exists():
                return cand
    return None


def resolve_invocation(line: str):
    """Source file of the script/module a shell line runs, if any."""
    m = re.search(r"-m\s+([\w.]+)", line)
    if m:
        return resolve_module(m.group(1))
    m = re.search(r"((?:[\w.-]+/)+[\w.-]+\.(?:py|sh))", line)
    if m and (ROOT / m.group(1)).exists():
        return ROOT / m.group(1)
    return None


def check_code_blocks(errors):
    for name in CODE_CHECKED:
        md = ROOT / name
        if not md.exists():
            errors.append(f"{name}: missing (docs tier expects it)")
            continue
        for block in FENCE_RE.findall(md.read_text()):
            # join shell line continuations so flags meet their command
            block = block.replace("\\\n", " ")
            for path in PATH_RE.findall(block):
                if not (ROOT / path).exists():
                    errors.append(f"{name}: code block references missing "
                                  f"path {path}")
            for mod in MODULE_RE.findall(block):
                if resolve_module(mod) is None:
                    errors.append(f"{name}: code block references missing "
                                  f"module {mod}")
            for line in block.splitlines():
                flags = FLAG_RE.findall(line)
                if not flags:
                    continue
                src = resolve_invocation(line)
                if src is None:
                    continue
                text = src.read_text()
                for flag in flags:
                    if flag not in text:
                        errors.append(f"{name}: {src.relative_to(ROOT)} has "
                                      f"no flag {flag}")


def check_serve_flags(errors):
    doc = ROOT / SERVING_DOC
    if not doc.exists():
        errors.append(f"{SERVING_DOC}: missing (the serve CLI reference)")
        return
    text = doc.read_text()
    for flag in ADD_ARG_RE.findall((ROOT / SERVE_CLI).read_text()):
        if f"`{flag}`" not in text:
            errors.append(f"{SERVING_DOC}: serve CLI flag {flag} "
                          f"undocumented (added in {SERVE_CLI}, no "
                          "backticked mention in the flag reference)")


def check_store_flags(errors):
    """Every store/plan-family serve flag is documented in the store
    reference — and every flag the check requires still exists in the
    CLI (a removed flag fails here, not silently)."""
    cli = (ROOT / SERVE_CLI).read_text()
    cli_flags = set(ADD_ARG_RE.findall(cli))
    doc = ROOT / STORES_DOC
    if not doc.exists():
        errors.append(f"{STORES_DOC}: missing (the compressed-store "
                      "reference)")
        return
    text = doc.read_text()
    for flag in STORE_FLAGS:
        if flag not in cli_flags:
            errors.append(f"scripts/check_docs.py STORE_FLAGS lists {flag} "
                          f"but {SERVE_CLI} no longer defines it")
        if f"`{flag}`" not in text:
            errors.append(f"{STORES_DOC}: store flag {flag} undocumented "
                          "(no backticked mention)")


def main() -> int:
    errors: list = []
    check_links(errors)
    check_code_blocks(errors)
    check_serve_flags(errors)
    check_store_flags(errors)
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        return 1
    print("docs OK: links + README code references + serve CLI flag "
          "coverage + store flag coverage resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
