#!/usr/bin/env python
"""CI guard: every (apply_mode, store_dtype) combination has a parity test,
and every mixer kind has a serving-differential parity test.

Purely static (no jax import — runs in ~10 ms like check_docs.py):

  * the required store matrix is read from the source of truth — the
    ``APPLY_MODES`` and ``STORE_DTYPES`` tuples of ``ResMoEConfig``
    (``configs/base.py``) — so ADDING a new apply mode or store dtype
    fails CI until a parity test covers it;
  * the required mixer rows come from ``MIXER_KINDS``
    (``models/transformer.py``) — adding a mixer fails CI until the zoo
    differential suite covers it end-to-end through ContinuousServer;
  * the required speculative-decoding rows come from ``SPEC_PARITY_MODES``
    (``launch/spec.py``) crossed with ``STORE_DTYPES`` — every restore-free
    verifier path x store dtype needs a spec-vs-plain token-identity test;
  * the required plan-trimming rows come from ``TRIM_TIERS``
    (``core/plan.py``) — every trimming tier (rank / dtype / expert /
    block) needs a differential test of the per-layer-plan store;
  * coverage is declared in test docstrings/comments with the markers

        # PARITY: <apply_mode>/<store_dtype>
        # PARITY: mixer/<mixer_kind>
        # PARITY: spec/<apply_mode>-<store_dtype>
        # PARITY: plan/<trim_tier>

    placed on the test that asserts that combination's output parity
    (e.g. tests/test_quant.py covers the int8 column, tests/test_moe.py
    and tests/test_moe_token.py the fp32 one, tests/test_serve.py's zoo
    suite the mixer rows and its spec_k parametrization the spec rows).

Run directly or via ``scripts/ci.sh docs`` / ``scripts/ci.sh all``.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
MARKER_RE = re.compile(r"#\s*PARITY:\s*([\w-]+)\s*/\s*([\w-]+)")


def _tuple_of_strings(source: str, name: str, path: Path):
    """First `<name> = ("a", "b", ...)` assignment in a module, via ast."""
    tree = ast.parse(source)
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if name in targets and isinstance(node.value, ast.Tuple):
                return tuple(ast.literal_eval(node.value))
    raise SystemExit(f"FAIL could not find tuple {name} in {path}")


def main() -> int:
    base = ROOT / "src/repro/configs/base.py"
    source = base.read_text()
    modes = _tuple_of_strings(source, "APPLY_MODES", base)
    dtypes = _tuple_of_strings(source, "STORE_DTYPES", base)
    required = {(m, d) for m in modes for d in dtypes}

    tfm = ROOT / "src/repro/models/transformer.py"
    kinds = _tuple_of_strings(tfm.read_text(), "MIXER_KINDS", tfm)
    required |= {("mixer", k) for k in kinds}

    spec = ROOT / "src/repro/launch/spec.py"
    spec_modes = _tuple_of_strings(spec.read_text(), "SPEC_PARITY_MODES",
                                   spec)
    required |= {("spec", f"{m}-{d}") for m in spec_modes for d in dtypes}

    plan = ROOT / "src/repro/core/plan.py"
    tiers = _tuple_of_strings(plan.read_text(), "TRIM_TIERS", plan)
    required |= {("plan", t) for t in tiers}

    covered = {}
    for test in sorted((ROOT / "tests").glob("test_*.py")):
        for m, d in MARKER_RE.findall(test.read_text()):
            covered.setdefault((m, d), []).append(test.name)

    unknown = sorted(set(covered) - required)
    missing = sorted(required - set(covered))
    for m, d in unknown:
        print(f"FAIL marker for unknown combination {m}/{d} in "
              f"{', '.join(covered[(m, d)])} (typo, or a removed mode?)")
    for m, d in missing:
        if m == "mixer":
            print(f"FAIL no serving-differential parity test declared for "
                  f"mixer kind {d!r} — add a zoo test and mark it "
                  f"'# PARITY: mixer/{d}'")
        elif m == "spec":
            print(f"FAIL no speculative-decoding parity test declared for "
                  f"{d} — add a spec_k differential and mark it "
                  f"'# PARITY: spec/{d}'")
        elif m == "plan":
            print(f"FAIL no differential test declared for plan trimming "
                  f"tier {d!r} (TRIM_TIERS, core/plan.py) — add one and "
                  f"mark it '# PARITY: plan/{d}'")
        else:
            print(f"FAIL no parity test declared for apply_mode={m} "
                  f"store_dtype={d} — add one and mark it '# PARITY: {m}/{d}'")
    if unknown or missing:
        return 1
    print(f"parity matrix OK: {len(modes)} apply modes x {len(dtypes)} "
          f"store dtypes + {len(kinds)} mixer kinds + {len(spec_modes)} "
          f"spec verifier modes x {len(dtypes)} dtypes + {len(tiers)} "
          "plan trimming tiers all covered")
    return 0


if __name__ == "__main__":
    sys.exit(main())
