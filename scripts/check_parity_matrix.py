#!/usr/bin/env python
"""CI guard: every (apply_mode, store_dtype) combination has a parity test.

Purely static (no jax import — runs in ~10 ms like check_docs.py):

  * the required matrix is read from the source of truth — the
    ``APPLY_MODES`` and ``STORE_DTYPES`` tuples of ``ResMoEConfig``
    (``configs/base.py``) — so ADDING a new apply mode or store dtype
    fails CI until a parity test covers it;
  * coverage is declared in test docstrings/comments with the marker

        # PARITY: <apply_mode>/<store_dtype>

    placed on the test that asserts that combination's output parity
    (e.g. tests/test_quant.py covers the int8 column, tests/test_moe.py
    and tests/test_moe_token.py the fp32 one).

Run directly or via ``scripts/ci.sh docs`` / ``scripts/ci.sh all``.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
MARKER_RE = re.compile(r"#\s*PARITY:\s*([\w-]+)\s*/\s*([\w-]+)")


def _tuple_of_strings(source: str, name: str, path: Path):
    """First `<name> = ("a", "b", ...)` assignment in a module, via ast."""
    tree = ast.parse(source)
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if name in targets and isinstance(node.value, ast.Tuple):
                return tuple(ast.literal_eval(node.value))
    raise SystemExit(f"FAIL could not find tuple {name} in {path}")


def main() -> int:
    base = ROOT / "src/repro/configs/base.py"
    source = base.read_text()
    modes = _tuple_of_strings(source, "APPLY_MODES", base)
    dtypes = _tuple_of_strings(source, "STORE_DTYPES", base)
    required = {(m, d) for m in modes for d in dtypes}

    covered = {}
    for test in sorted((ROOT / "tests").glob("test_*.py")):
        for m, d in MARKER_RE.findall(test.read_text()):
            covered.setdefault((m, d), []).append(test.name)

    unknown = sorted(set(covered) - required)
    missing = sorted(required - set(covered))
    for m, d in unknown:
        print(f"FAIL marker for unknown combination {m}/{d} in "
              f"{', '.join(covered[(m, d)])} (typo, or a removed mode?)")
    for m, d in missing:
        print(f"FAIL no parity test declared for apply_mode={m} "
              f"store_dtype={d} — add one and mark it '# PARITY: {m}/{d}'")
    if unknown or missing:
        return 1
    print(f"parity matrix OK: {len(modes)} apply modes x {len(dtypes)} "
          "store dtypes all covered")
    return 0


if __name__ == "__main__":
    sys.exit(main())
