from .analyze import analyze_record, load_all, markdown_table, model_flops

__all__ = ["analyze_record", "load_all", "markdown_table", "model_flops"]
