"""Roofline analysis from the dry-run artifacts (deliverable g).

For each (arch, shape, mesh) JSON produced by repro.launch.dryrun:

  compute_term   = HLO_FLOPs_per_device / PEAK_FLOPS
  memory_term    = HLO_bytes_per_device / HBM_BW
  collective_term= collective_bytes_per_device / ICI_BW

(The compiled module is the per-device SPMD program, so all three numbers
are per-chip; dividing by per-chip peaks gives seconds directly —
equivalent to the global form chips x peak.)

MODEL_FLOPS uses the 6·N·D (train) / 2·N·D (inference) convention with
N = active params, D = tokens, PLUS the quadratic attention term — and is
reported per device.  ratio = MODEL_FLOPS / HLO_FLOPs flags remat/dispatch
waste (>1 means the compiler-counted FLOPs UNDERCOUNT, e.g. nested-loop
bodies counted once — see the caveat column).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from .hw import HBM_BW, HBM_BYTES, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


def _attention_flops(cfg, shape) -> float:
    """Global attention-score/value FLOPs for the step (fwd; x3 for train).

    Per layer: 4 * B * H * head_dim * sum_q kv(q), with kv(q) = min(q, w)
    under a causal window w.  Recurrent/rwkv mixers contribute ~O(d*64) per
    token — folded into the matmul term via num_active_params.
    """
    import repro.models.transformer as tfm

    specs = tfm.layer_specs(cfg)
    tot = 0.0
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.v_head_dim if cfg.attention_type == "mla" else cfg.head_dim
    for spec in specs:
        if spec.mixer not in ("gqa", "mla"):
            continue
        w = spec.window if spec.window < (1 << 29) else s
        w = min(w, s)
        if shape.kind == "decode":
            kv_sum = w  # one query against the (windowed) cache
        else:
            # sum over q in [0, s) of min(q, w)
            kv_sum = w * s - w * w / 2 if w < s else s * s / 2
        tot += 4.0 * b * cfg.num_heads * hd * kv_sum
    return tot


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic MODEL_FLOPS for the whole step (global, all chips)."""
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.num_active_params()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        mm = 6.0 * n_active * tokens
        att = 3.0 * _attention_flops(cfg, shape)
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        mm = 2.0 * n_active * tokens
        att = _attention_flops(cfg, shape)
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mm = 2.0 * n_active * tokens
        att = _attention_flops(cfg, shape)
    return mm + att


def analyze_record(rec: Dict, chips: int = 256) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    hc = rec.get("hlo_cost")
    if hc and hc.get("flops", 0) > 0:
        # trip-count-aware numbers (see repro.launch.hlo_cost — XLA's own
        # cost_analysis counts loop bodies once)
        flops = float(hc["flops"])
        byts = float(hc["bytes"])
        coll = float(hc["coll_total"])
    else:
        ca = rec.get("cost_analysis", {})
        flops = float(ca.get("flops", 0.0))
        byts = float(ca.get("bytes accessed", 0.0))
        coll = float(rec.get("collectives", {}).get("total_bytes", 0.0))
    compute_t = flops / PEAK_FLOPS_BF16
    memory_t = byts / HBM_BW
    collective_t = coll / ICI_BW_PER_LINK
    terms = {"compute": compute_t, "memory": memory_t, "collective": collective_t}
    dominant = max(terms, key=terms.get)
    mf_global = model_flops(rec["arch"], rec["shape"])
    mesh_chips = 512 if rec["mesh"] == "2x16x16" else 256
    mf_dev = mf_global / mesh_chips
    mem = rec.get("memory_analysis", {})
    resident = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0))
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": collective_t,
        "dominant": dominant,
        "bound_s": max(terms.values()),
        "model_flops_dev": mf_dev,
        "hlo_flops_dev": flops,
        "useful_ratio": (mf_dev / flops) if flops else float("nan"),
        "roofline_frac": (mf_dev / PEAK_FLOPS_BF16) / max(terms.values())
        if max(terms.values()) > 0 else float("nan"),
        "hbm_resident_gb": resident / 2**30,
        "fits_hbm": resident <= HBM_BYTES,
        "collective_detail": {
            k[5:]: v for k, v in (hc or {}).items()
            if k.startswith("coll_") and k != "coll_total" and v
        } or rec.get("collectives", {}).get("bytes", {}),
    }


def load_all(result_dir: str = "dryrun_results") -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        rec = json.load(open(f))
        a = analyze_record(rec)
        if a is not None:
            out.append(a)
    return out


def markdown_table(rows: List[Dict], mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "model/HLO flops | roofline frac | HBM GB | fits |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} | "
            f"{r['hbm_resident_gb']:.1f} | {'y' if r['fits_hbm'] else 'N'} |"
        )
    return "\n".join(lines)


def run():
    rows = load_all()
    out = []
    for r in rows:
        if r["mesh"] != "16x16":
            continue
        out.append((
            f"roofline/{r['arch']}/{r['shape']}",
            0,
            f"dom={r['dominant']};bound_s={r['bound_s']:.3e};"
            f"frac={r['roofline_frac']:.3f}",
        ))
    return out


if __name__ == "__main__":
    rows = load_all()
    print(markdown_table(rows))
