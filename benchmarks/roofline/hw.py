"""TPU v5e hardware constants for the roofline analysis (per chip)."""

PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s per link
HBM_BYTES = 16 * 2**30  # capacity, for fits-on-chip checks
