"""Paper Table 12: analytic FLOPs per token for the expert path.

Adds what the paper does NOT have: the restore-free fused path (x@Wc +
(x@V^T)@U^T) and the shared-base variant, which make ResMoE-SVD *cheaper*
than the dense model instead of more expensive (DESIGN.md §4.3)."""
from __future__ import annotations

from repro.core.residual import svd_rank_for_ratio


def expert_flops(d: int, f: int, n_mats: int = 3) -> float:
    return 2.0 * n_mats * d * f  # per token per expert


def run():
    rows = []
    for name, (d, f, k, e) in {
        "mixtral": (4096, 14336, 2, 8),
        "deepseek-v3": (7168, 2048, 8, 256),
    }.items():
        base = k * expert_flops(d, f)
        r = svd_rank_for_ratio(f, 3 * d, 0.25)
        lowrank = 2.0 * r * (3 * d + f)  # per token per expert (u/v products)
        rows.append((f"T12/{name}/dense", 0, f"{base:.3e}"))
        rows.append((f"T12/{name}/ResMoE(UP,restored)", 0, f"{base:.3e}"))
        # paper's SVD: center + per-expert low-rank RESTORE then dense matmul
        restore = k * (expert_flops(d, f) + 0)  # restored weights, same matmul
        rows.append((f"T12/{name}/ResMoE(SVD,restored)", 0, f"{restore:.3e}"))
        # ours: fused, never restores
        fused = k * (expert_flops(d, f) + lowrank)
        rows.append((f"T12/{name}/ResMoE(SVD,fused)", 0, f"{fused:.3e}"))
        # ours: shared-base — w1/w3 center matmuls once per token, not per k
        shared = (2 * 2.0 * d * f) + k * (2.0 * d * f + lowrank)
        rows.append((f"T12/{name}/ResMoE(SVD,fused_shared)", 0, f"{shared:.3e}"))
        rows.append((f"T12/{name}/fused_shared_vs_dense", 0,
                     round(shared / base, 3)))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
