"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time
from typing import Callable, Dict

import numpy as np


def timer(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def trained_like_bank(rng, n_experts: int, d: int, f: int, glu: bool = True,
                      share: float = 1.0, distinct: float = 0.45,
                      noise: float = 0.15) -> Dict[str, np.ndarray]:
    """Synthetic bank mimicking trained MoE experts.

    Trained experts (esp. Mixtral's, initialized by upcycling a dense model)
    share a strong common component; each adds expert-specific structure.
    Rows are shuffled per expert so the alignment problem is non-trivial.
    """
    dd = (3 if glu else 2) * d
    base = rng.normal(size=(f, dd)) * share
    bank = {"w1": [], "w2": []}
    if glu:
        bank["w3"] = []
    for _ in range(n_experts):
        own = distinct * rng.normal(size=(f, dd))
        design = (base + own + noise * rng.normal(size=(f, dd)))[rng.permutation(f)]
        bank["w1"].append(design[:, :d].T)
        if glu:
            bank["w3"].append(design[:, d : 2 * d].T)
            bank["w2"].append(design[:, 2 * d :])
        else:
            bank["w2"].append(design[:, d:])
    return {k: np.stack(v).astype(np.float32) for k, v in bank.items()}


def emit(rows, header=("name", "us_per_call", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
