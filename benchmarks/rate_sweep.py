"""Paper Figure 4: quality vs compression rate.

Approximation-error analog of the LAMBADA sweep: ResMoE(UP) at rate r is
compared against direct UP at rates r and r+0.2 — the paper's headline is
that ResMoE at 10% matches baselines at 30%."""
from __future__ import annotations

import numpy as np

from repro.core.baselines import run_baseline
from repro.core.compress import compress_bank, design_matrices

from .common import trained_like_bank


def run(seed: int = 0):
    rng = np.random.default_rng(seed)
    bank = trained_like_bank(rng, n_experts=8, d=64, f=224, glu=True)
    design = design_matrices(bank)
    rows = []
    # the metric goes in the VALUE column (the JSON artifact's numeric
    # field); derived carries provenance only — BENCH rows with a 0 value
    # and the number hidden in derived are unplottable downstream
    prov = "approximation_error vs design matrices"
    for rate in (0.1, 0.2, 0.3, 0.4, 0.5):
        res = compress_bank(bank, "up", rate)
        up = run_baseline("up", design, rate)
        svd = compress_bank(bank, "svd", rate)
        rows.append((f"F4/rate={rate}/ResMoE(UP)",
                     round(res.approximation_error(design), 4), prov))
        rows.append((f"F4/rate={rate}/UP",
                     round(up.approximation_error(design), 4), prov))
        rows.append((f"F4/rate={rate}/ResMoE(SVD)",
                     round(svd.approximation_error(design), 4), prov))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
