"""Paper Tables 2/3 (+7) proxy: downstream quality after compression.

Protocol (scaled to CPU): train a reduced Mixtral-family MoE on the
synthetic LM stream until the loss is well below chance, then compress the
experts with each method at 25% and evaluate held-out NLL and next-token
accuracy, zero-shot (no retraining) — the paper's exact setting in miniature.
Expected ordering (Table 3): ResMoE(UP) ~ dense > ResMoE(SVD) > merge > UP
>> SP/SVD-direct.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core.baselines import run_baseline
from repro.core.compress import design_matrices, split_design
from repro.data import make_pipeline
from repro.launch.train import run_training
from repro.models import build_model, compress_model_params


def _eval(model, params, pipe, steps=3, apply_mode=None):
    nll = 0.0
    acc = 0.0
    fwd = jax.jit(lambda p, b: model.forward(p, b, apply_mode=apply_mode)[0])
    for i in range(5000, 5000 + steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        logits = fwd(params, batch).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][..., None], -1)[..., 0]
        nll += float((lse - gold).mean())
        acc += float((logits.argmax(-1) == batch["labels"]).mean())
    return nll / steps, acc / steps


def _direct_apply(params, method: str, keep: float) -> Dict:
    """Apply a direct baseline to the expert banks in-place (copy)."""
    p = jax.tree_util.tree_map(lambda x: np.array(x, copy=True), params)
    f = p["segments"][0]["slots"][0]["ffn"]
    reps, n_exp = f["w1"].shape[:2]
    for r in range(reps):
        bank = {k: f[k][r] for k in ("w1", "w2", "w3")}
        design = design_matrices(bank)
        res = run_baseline(method, design, keep)
        for k in range(n_exp):
            w = split_design(res.approx[k], {m: bank[m][0] for m in bank})
            for m in bank:
                f[m][r][k] = w[m]
    return p


def run(steps: int = 150, keep: float = 0.25, seed: int = 0):
    out = run_training("mixtral-8x7b", steps=steps, seq_len=64, global_batch=4,
                       lr=3e-3, seed=seed, log_every=50)
    cfg = reduced_config("mixtral-8x7b")
    model = build_model(cfg)
    params = out["params"]
    pipe = make_pipeline(cfg, 64, 4, seed=seed)
    rows = []
    nll, acc = _eval(model, params, pipe)
    rows.append(("T3/dense", 0, f"nll={nll:.4f};acc={acc:.4f}"))

    for meth, label in [("up", "UP"), ("sp", "SP"), ("svd", "SVD"),
                        ("msmoe", "M-SMoE"), ("meo", "MEO")]:
        p2 = _direct_apply(params, meth, keep)
        nll, acc = _eval(model, p2, pipe)
        rows.append((f"T3/{label}", 0, f"nll={nll:.4f};acc={acc:.4f}"))

    for meth, mode in [("up", "restored"), ("svd", "fused")]:
        c = dataclasses.replace(
            cfg, resmoe=dataclasses.replace(cfg.resmoe, method=meth,
                                            keep_ratio=keep, apply_mode=mode))
        cp, rep = compress_model_params(params, c)
        nll, acc = _eval(model, cp, pipe, apply_mode=mode)
        rows.append((f"T3/ResMoE({meth.upper()})", 0,
                     f"nll={nll:.4f};acc={acc:.4f}"))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
