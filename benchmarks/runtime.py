"""Paper Table 11: runtime of the forward paths (CPU wall-clock proxy).

The paper reports Mixtral end-to-end runtime per method on A100s; here we
time our reduced-config MoE forward under each expert path plus the Pallas
kernels (interpret mode — correctness-representative, not TPU-timed)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import build_model, compress_model_params

from .common import timer


def run(seed: int = 0):
    rng = np.random.default_rng(seed)
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd", keep_ratio=0.25))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)),
                                   jnp.int32)}
    rows = []

    def bench(name, p, mode):
        fwd = jax.jit(lambda pp, b: model.forward(pp, b, apply_mode=mode)[0])
        fwd(p, batch).block_until_ready()
        us = timer(lambda: fwd(p, batch).block_until_ready(), repeats=5)
        rows.append((f"T11/forward/{name}", round(us, 1), ""))

    bench("dense", params, None)
    bench("ResMoE(restored)", cp, "restored")
    bench("ResMoE(fused)", cp, "fused")
    bench("ResMoE(fused_shared)", cp, "fused_shared")

    # kernel microbench (interpret mode)
    from repro.kernels import lowrank_restore_matmul

    x = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 512)), jnp.float32)
    us = timer(lambda: lowrank_restore_matmul(x, w, a, b,
                                              interpret=True).block_until_ready(),
               repeats=3)
    rows.append(("T11/kernel/lowrank_interpret", round(us, 1), ""))
    ref = jax.jit(lambda: (x @ w + (x @ a) @ b))
    ref().block_until_ready()
    us = timer(lambda: ref().block_until_ready(), repeats=5)
    rows.append(("T11/kernel/lowrank_xla", round(us, 1), ""))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
