"""Paper Table 11: runtime of the forward paths (CPU wall-clock proxy).

The paper reports Mixtral end-to-end runtime per method on A100s; here we
time our reduced-config MoE forward under each expert path plus the Pallas
kernels (interpret mode — correctness-representative, not TPU-timed)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import (
    build_model,
    compress_model_params,
    quantize_compressed_params,
)

from .common import timer


def run(seed: int = 0):
    rng = np.random.default_rng(seed)
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd", keep_ratio=0.25))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)),
                                   jnp.int32)}
    rows = []

    def bench(name, p, mode):
        fwd = jax.jit(lambda pp, b: model.forward(pp, b, apply_mode=mode)[0])
        fwd(p, batch).block_until_ready()
        us = timer(lambda: fwd(p, batch).block_until_ready(), repeats=5)
        rows.append((f"T11/forward/{name}", round(us, 1), ""))

    bench("dense", params, None)
    bench("ResMoE(restored)", cp, "restored")
    bench("ResMoE(fused)", cp, "fused")
    bench("ResMoE(fused_shared)", cp, "fused_shared")
    bench("ResMoE(fused_kernel)", cp, "fused_kernel")
    # int8 store through the dequant-fused grouped kernel (DESIGN.md §9)
    qp = quantize_compressed_params(cp)
    bench("ResMoE(fused_kernel,int8)", qp, "fused_kernel")
    bench("ResMoE(fused,int8)", qp, "fused")

    # kernel microbench (interpret mode)
    from repro.kernels import lowrank_restore_matmul

    x = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 512)), jnp.float32)
    us = timer(lambda: lowrank_restore_matmul(x, w, a, b,
                                              interpret=True).block_until_ready(),
               repeats=3)
    rows.append(("T11/kernel/lowrank_interpret", round(us, 1), ""))
    ref = jax.jit(lambda: (x @ w + (x @ a) @ b))
    ref().block_until_ready()
    us = timer(lambda: ref().block_until_ready(), repeats=5)
    rows.append(("T11/kernel/lowrank_xla", round(us, 1), ""))

    rows.extend(grouped_comparison(rng))
    rows.extend(grouped_roofline_mixtral())
    rows.extend(quant_kernel_comparison(rng))
    rows.extend(quant_roofline_mixtral())
    rows.extend(token_decode_comparison(rng, cfg=cfg, cp=cp, qp=qp))
    rows.extend(token_decode_roofline_mixtral())
    rows.extend(ep_vs_gspmd_compressed())
    return rows


def token_decode_comparison(rng, ts=(1, 4, 8, 32), cfg=None, cp=None,
                            qp=None):
    """Decode-shape MoE layer: ragged token path vs dispatched vs restored.

    Times ONE compressed MoE layer (the reduced-Mixtral layer-0 store) at
    decode token counts T ∈ {1, 4, 8, 32} under (a) the ragged per-token
    path (apply_mode="fused_token", kernels/resmoe_token.py), (b) the
    dispatched grouped kernel with the token gate disabled
    (token_path_max_tokens=0), (c) the in-graph restored path, and (d) the
    int8 store through the dequant-fused token kernel (token_int8).
    Interpret-mode wall-clock is a correctness proxy, NOT a TPU
    projection — token_decode_roofline_mixtral / quant_roofline_mixtral
    state the hardware claims.

    ``cfg``/``cp``/``qp`` let run() share its already-compressed stores;
    built here only when invoked standalone.
    """
    if cfg is None or cp is None:
        cfg = reduced_config("mixtral-8x7b")
        cfg = dataclasses.replace(
            cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                            keep_ratio=0.25))
        model = build_model(cfg)
        params, _ = model.init_split(jax.random.PRNGKey(0))
        cp, _ = compress_model_params(params, cfg)
    if qp is None:
        qp = quantize_compressed_params(cp)
    from repro.models.moe import moe_layer

    bank = jax.tree_util.tree_map(
        lambda a: jnp.asarray(a[0]), cp["segments"][0]["slots"][0]["ffn"])
    qbank = jax.tree_util.tree_map(
        lambda a: jnp.asarray(a[0]), qp["segments"][0]["slots"][0]["ffn"])
    rows = []
    variants = (
        ("token", "fused_token", None, bank),
        ("token_int8", "fused_token", None, qbank),
        ("dispatched_kernel", "fused_kernel", 0, bank),
        ("dispatched_kernel_int8", "fused_kernel", 0, qbank),
        ("restored", "restored", 0, bank),
    )
    for t in ts:
        x = jnp.asarray(rng.normal(size=(t, 1, cfg.d_model)), jnp.float32)
        for name, mode, thr, bk in variants:
            c2 = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe,
                                             token_path_max_tokens=thr))
            fn = jax.jit(lambda b, xx, c=c2, m=mode:
                         moe_layer(b, xx, c, apply_mode=m)[0])
            fn(bk, x).block_until_ready()
            us = timer(lambda: fn(bk, x).block_until_ready(), repeats=5)
            rows.append((f"T11/token_decode/T{t}_{name}_us", round(us, 1), ""))
    return rows


def token_decode_roofline_mixtral(ts=(1, 4, 8, 32), e=8, k=2, d=4096,
                                  f=14336, keep=0.25, dtype_bytes=4):
    """Analytic HBM bytes + FLOPs per MoE layer at true Mixtral decode shapes.

    Token path vs the dispatched grouped kernel, per forward pass over all
    three expert-FFN segments (w1, w3, w2):

      * dispatched — capacity padding makes the bank process E*C rows
        (C >= 8) for T real tokens, and at f32 Mixtral shapes the
        contraction never fits one k block, so the grouped kernel
        re-streams each center segment once per EXPERT per row tile
        (grouped_roofline_mixtral's own accounting). All E experts'
        low-rank factors stream regardless of routing.
      * token — every center segment is ONE dense [T, ·] matmul (read
        once; the w2 center product runs on the gate-combined hbar), and
        the ragged kernel gathers at most min(T*k, E) factor sets (pairs
        are expert-sorted, so consecutive same-expert grid steps elide the
        refetch).

    ``T{t}_bytes_x > 1`` = the token path moves strictly fewer HBM bytes.
    """
    from repro.configs.base import MoEConfig
    from repro.kernels.resmoe_grouped import _pick_bk
    from repro.models.moe import expert_capacity

    r = int(keep * d * f / (d + f))  # svd_rank_for_ratio's budget rule
    rp = r + ((-r) % 128)
    m = MoEConfig(num_experts=e, top_k=k, expert_d_ff=f,
                  capacity_factor=1.25)
    segments = ((d, f), (d, f), (f, d))  # w1, w3, w2
    rows = []
    for t in ts:
        pairs = t * k
        cap = expert_capacity(t, m)
        bm = min(128, max(8, -(-cap // 8) * 8))
        n_tiles_m = -(-cap // bm)
        disp_bytes = disp_flops = 0
        for kk, nn in segments:
            kp = kk + ((-kk) % 128)
            n_k = -(-kp // _pick_bk(kp, bm, 128, rp, dtype_bytes))
            passes = 1 if n_k == 1 else e  # single k block => reuse over E
            disp_bytes += n_tiles_m * passes * kk * nn * dtype_bytes
            disp_bytes += e * (kk + nn) * r * dtype_bytes  # all E factors
            disp_bytes += e * cap * (kk + nn) * dtype_bytes  # acts in/out
            disp_flops += 2 * e * cap * (kk * nn + r * (kk + nn))
        uniq = min(pairs, e)
        tok_bytes = tok_flops = 0
        for kk, nn in segments:
            tok_bytes += kk * nn * dtype_bytes  # center: once, per token batch
            tok_flops += 2 * t * kk * nn  # center matmuls run on T rows
            tok_flops += 2 * pairs * r * (kk + nn)
        # per-pair kernel blocks: v1, v3, v2 and ONE u block shared by the
        # w1/w3 corrections and the t2 accumulation — one fetch per
        # distinct expert thanks to the expert-sorted grid
        tok_bytes += uniq * r * (3 * d + f) * dtype_bytes
        tok_bytes += (pairs * (2 * d + f) + t * (3 * f + 2 * d)) * dtype_bytes
        rows.append((f"T11/token_decode_roofline/T{t}_token_GB",
                     round(tok_bytes / 1e9, 3), f"flops={tok_flops:.3e}"))
        rows.append((f"T11/token_decode_roofline/T{t}_dispatched_GB",
                     round(disp_bytes / 1e9, 3), f"flops={disp_flops:.3e}"))
        rows.append((f"T11/token_decode_roofline/T{t}_bytes_x",
                     round(disp_bytes / tok_bytes, 2),
                     "token-path advantage (>1 = token path wins)"))
    return rows


def ep_vs_gspmd_compressed(mesh_shape=(2, 4)):
    """EP-compressed vs GSPMD-compressed forward on a (data, model) mesh.

    Compiles the same ResMoE-SVD fused forward twice — once with the EP
    gate closed (GSPMD lowers the sharded store) and once with it open
    (moe_ep.py shard_map: replicated center, sharded u/v, one [T_loc, d]
    psum per layer, DESIGN.md §6) — and reports end-to-end wall-clock +
    whole-model collective bytes, plus the §4.3 cost model's collective
    bytes of ONE standalone MoE layer (lowered in isolation, so
    attention/embedding collectives cannot pollute the per-layer number).

    Needs prod(mesh_shape) devices; on a bare CPU run it emits a skip row
    (rerun under XLA_FLAGS=--xla_force_host_platform_device_count=8).
    """
    need = int(np.prod(mesh_shape))
    if len(jax.devices()) < need:
        return [("T11/ep_compressed/skipped", 0.0,
                 f"needs {need} devices; rerun under XLA_FLAGS="
                 f"--xla_force_host_platform_device_count={need}")]

    from repro.launch.hlo_cost import analyze_hlo_text
    from repro.launch.mesh import make_mesh
    from repro.models.model import abstract_compressed_params
    from repro.sharding import make_rules, shardings_from_axes, use_rules

    rng = np.random.default_rng(0)
    base = reduced_config("mixtral-8x7b")
    base = dataclasses.replace(
        base, resmoe=dataclasses.replace(base.resmoe, method="svd",
                                         keep_ratio=0.25))
    model = build_model(base)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, base)
    batch = {"tokens": jnp.asarray(rng.integers(0, base.vocab_size, (4, 64)),
                                   jnp.int32)}
    mesh = make_mesh(mesh_shape, ("data", "model"))
    rules = make_rules(mesh)
    # layer-0 slice of the stacked store, for the standalone-layer lowering
    bank = jax.tree_util.tree_map(
        lambda a: jnp.asarray(a[0]), cp["segments"][0]["slots"][0]["ffn"])
    x_layer = jnp.asarray(rng.normal(size=(4, 64, base.d_model)), jnp.float32)

    rows = []
    # same params/batch; only the EP gate differs between the two variants
    for name, thr in (("gspmd", 1 << 30), ("ep", 1)):
        cfg = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, ep_min_local_tokens=thr))
        m = build_model(cfg)
        _, axes = abstract_compressed_params(cfg)
        sh = shardings_from_axes(axes, rules, cp)

        def fwd(p, b, m=m):
            with use_rules(rules):
                return m.forward(p, b, apply_mode="fused")[0]

        with mesh:
            p = jax.device_put(cp, sh)
            compiled = jax.jit(fwd).lower(p, batch).compile()
            compiled(p, batch).block_until_ready()
            us = timer(lambda: compiled(p, batch).block_until_ready(),
                       repeats=5)
        cost = analyze_hlo_text(compiled.as_text())
        rows.append((f"T11/ep_compressed/{name}_us", round(us, 1),
                     f"coll_total_model={cost['coll_total']:.3e}B"))

        from repro.models.moe import moe_layer

        def layer(p, xx, m=cfg):
            with use_rules(rules):
                return moe_layer(p, xx, m, apply_mode="fused")[0]

        with mesh:
            ltext = jax.jit(layer).lower(bank, x_layer).compile().as_text()
        lcost = analyze_hlo_text(ltext)
        rows.append((f"T11/ep_compressed/{name}_coll_B_per_moe_layer",
                     round(lcost["coll_total"], 1),
                     f"all_reduce={lcost['coll_all-reduce']:.3e} "
                     f"all_gather={lcost['coll_all-gather']:.3e} "
                     f"all_to_all={lcost['coll_all-to-all']:.3e}"))
    return rows


def grouped_comparison(rng, e=8, c=64, d=256, f=448, r=64):
    """Grouped-kernel vs einsum-fused vs in-graph-restored expert bank.

    Small (CPU-feasible) bank: wall-clock of (a) the grouped Pallas kernel
    in interpret mode, (b) the identical math as XLA einsums (the `fused`
    path's segment shape), (c) the restored path (materialize W + A@B per
    expert, then a grouped dense einsum). Interpret-mode wall-clock is a
    correctness proxy, NOT a TPU projection — see grouped_roofline_mixtral
    for the hardware accounting.
    """
    import jax

    from repro.kernels import grouped_lowrank_matmul

    xg = jnp.asarray(rng.normal(size=(e, c, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, f)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(e, d, r)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(e, r, f)), jnp.float32)
    rows = []

    us = timer(lambda: grouped_lowrank_matmul(
        xg, w, a, b, interpret=True).block_until_ready(), repeats=3)
    rows.append(("T11/grouped/kernel_interpret", round(us, 1), ""))

    einsum = jax.jit(lambda: jnp.einsum("ecd,df->ecf", xg, w) + jnp.einsum(
        "ecr,erf->ecf", jnp.einsum("ecd,edr->ecr", xg, a), b))
    einsum().block_until_ready()
    us = timer(lambda: einsum().block_until_ready(), repeats=5)
    rows.append(("T11/grouped/einsum_xla", round(us, 1), ""))

    restored = jax.jit(lambda: jnp.einsum(
        "ecd,edf->ecf", xg, w[None] + jnp.einsum("edr,erf->edf", a, b)))
    restored().block_until_ready()
    us = timer(lambda: restored().block_until_ready(), repeats=5)
    rows.append(("T11/grouped/restored_xla", round(us, 1), ""))
    return rows


def quant_kernel_comparison(rng, e=8, c=64, d=256, f=448, r=64):
    """Dequant-fused int8 grouped kernel vs its fp32 twin (interpret mode).

    Same bank shapes as grouped_comparison; the int8 variant streams the
    center/factor tiles as int8 and folds the per-channel scales into the
    f32 accumulators (kernels/resmoe_grouped.py::grouped_lowrank_matmul_q8).
    Interpret-mode wall-clock is a correctness proxy; the HBM-bytes claim
    is quant_roofline_mixtral.
    """
    from repro.core.quant import quantize_int8
    from repro.kernels import grouped_lowrank_matmul, grouped_lowrank_matmul_q8

    xg = jnp.asarray(rng.normal(size=(e, c, d)), jnp.float32)
    wf = rng.normal(size=(d, f)).astype(np.float32)
    af = rng.normal(size=(e, d, r)).astype(np.float32)
    bf = rng.normal(size=(e, r, f)).astype(np.float32)
    wq, sw = quantize_int8(wf, -2)   # per output channel f
    aq, sa = quantize_int8(af, -2)   # per rank channel r
    bq, sb = quantize_int8(bf, -1)   # per rank channel r
    rows = []

    us = timer(lambda: grouped_lowrank_matmul(
        jnp.asarray(xg), jnp.asarray(wf), jnp.asarray(af), jnp.asarray(bf),
        interpret=True).block_until_ready(), repeats=3)
    rows.append(("T11/quant/grouped_fp32_interpret", round(us, 1), ""))
    sab = jnp.asarray(sa * sb)
    us = timer(lambda: grouped_lowrank_matmul_q8(
        jnp.asarray(xg), jnp.asarray(wq), jnp.asarray(sw), jnp.asarray(aq),
        jnp.asarray(bq), sab, interpret=True).block_until_ready(), repeats=3)
    rows.append(("T11/quant/grouped_int8_interpret", round(us, 1), ""))
    return rows


def quant_roofline_mixtral(e=8, d=4096, f=14336, keep=0.25):
    """Factor HBM bytes of the serving store per MoE layer, fp32 vs int8.

    The factors — center segments (w1, w3, w2), ``u``, and the three ``v``
    segments — are everything the restore-free kernels stream per layer
    besides activations. int8 stores 1 byte/elem plus fp32 per-channel
    scale vectors (center: one scale per output channel; u/v: [E, r] rank
    scales), so the ratio sits just under 4x; the scales are O(channels),
    ~1e-4 of the factor payload at Mixtral-8x7B shapes. Asserted here
    (>= 3.5x, the acceptance floor) so the bench tier gates regressions
    that grow the scale payload.
    """
    r = int(keep * d * f / (d + f))  # svd_rank_for_ratio's budget rule
    factor_elems = 3 * d * f + e * f * r + 3 * e * r * d  # center + u + v
    scale_elems = (2 * f + d) + e * r + 3 * e * r  # center + u + v scales
    fp32_bytes = factor_elems * 4
    int8_bytes = factor_elems * 1 + scale_elems * 4
    ratio = fp32_bytes / int8_bytes
    assert ratio >= 3.5, (
        f"int8 store factor-byte advantage {ratio:.2f}x fell below the "
        "3.5x acceptance floor — scale payload grew?")
    return [
        ("T11/quant_roofline_mixtral/fp32_factor_GB",
         round(fp32_bytes / 1e9, 3), f"elems={factor_elems:.3e}"),
        ("T11/quant_roofline_mixtral/int8_factor_GB",
         round(int8_bytes / 1e9, 3),
         f"scale_elems={scale_elems:.3e} (fp32)"),
        ("T11/quant_roofline_mixtral/factor_bytes_x", round(ratio, 2),
         "int8 store advantage (>=3.5 asserted)"),
    ]


def paged_vs_sync_serving(seed: int = 0):
    """Paged continuous batching vs the slot-synchronous server, same HBM.

    Both servers drain the same Poisson-sampled request trace under the
    SAME KV-memory budget: the sync server spends it on 4 full ``max_seq``
    cache rows (4 x 256 = 1024 token positions), the paged server on a
    128-page x 8-token pool (the identical 1024 positions) shared by 24
    slots — a pool at 1/6 of ``num_slots * max_seq``. Real requests touch
    ~40 positions each, so the page pool turns the same bytes into 6x the
    decode concurrency: the attention FLOPs per token are unchanged, but
    the per-step fixed cost amortizes over 24 live rows instead of 4,
    which is what clears the >= 1.5x tokens/s acceptance bar. The paged
    side additionally replays a Poisson arrival trace through its
    admission queue (the sync oracle has no arrival support and gets the
    whole batch up front — a head start that only UNDERSTATES the paged
    advantage).

    Wall-clock excludes compilation: ``ContinuousServer.warmup()``
    pre-compiles every bucketed prefill shape plus the decode step (the
    finite-shape guarantee bucketing exists for), and the sync server is
    warmed on a short trace prefix covering both prompt shapes.

    The overlapped engine (launch/engine.py, DESIGN.md §13) then drains
    the SAME trace at the same pool geometry with per-token timestamps
    on: its decode thread never runs admission prefills or the per-step
    host readback, so the rows assert it sustains at least the sync
    paged throughput while strictly improving p99 inter-token latency —
    the sync scheduler stalls every live decode behind each arrival's
    prefill, which is exactly the tail the engine exists to cut.
    """
    import time

    from repro.launch.engine import OverlappedServer
    from repro.launch.serve import ContinuousServer, Request, Server

    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    max_seq, sync_slots, page_size = 256, 4, 8
    pool_pages = sync_slots * max_seq // page_size  # same token positions
    paged_slots = 24

    def trace(n):
        # decode-heavy requests (32 new tokens on a 4-8 token prompt): the
        # B=1 prefill costs the two servers identically, so a trace that is
        # mostly decode isolates the scheduling difference being measured
        prompts = [rng.integers(0, cfg.vocab_size, size=(int(rng.choice([4, 8])),))
                   .astype(np.int32) for _ in range(n)]
        arrivals = np.sort(rng.poisson(0.8, size=n)).tolist()
        return prompts, arrivals

    def requests(prompts):
        return [Request(prompt=p, max_new_tokens=32) for p in prompts]

    sync = Server(model, params, num_slots=sync_slots, max_seq=max_seq)
    paged = ContinuousServer(model, params, num_slots=paged_slots,
                             max_seq=max_seq, page_size=page_size,
                             pool_pages=pool_pages,
                             record_token_times=True)
    overlapped = OverlappedServer(model, params, num_slots=paged_slots,
                                  max_seq=max_seq, page_size=page_size,
                                  pool_pages=pool_pages, admit_batch=8,
                                  record_token_times=True)
    warm, _ = trace(4)
    sync.serve(requests(warm))
    # longest resume = longest prompt (8) + max_new (32): bounding warmup
    # there skips ~25 never-used prefill shapes' compiles
    paged.warmup(max_len=8 + 32)
    overlapped.warmup(max_len=8 + 32)

    # ONE trace, drained by both servers — otherwise speedup_x would also
    # measure the luck of two different prompt-length draws
    prompts, arrivals = trace(48)

    reqs = requests(prompts)
    t0 = time.perf_counter()
    sync.serve(reqs)
    dt_sync = time.perf_counter() - t0
    tok_sync = sum(len(r.output) for r in reqs)

    def intertoken_ms(reqs):
        deltas = [b - a for r in reqs
                  for a, b in zip(r.token_times, r.token_times[1:])]
        return (1e3 * float(np.percentile(deltas, 50)),
                1e3 * float(np.percentile(deltas, 99)))

    reqs = requests(prompts)
    t0 = time.perf_counter()
    paged.serve(reqs, arrival_steps=arrivals)
    dt_paged = time.perf_counter() - t0
    tok_paged = sum(len(r.output) for r in reqs)
    paged_out = [r.output for r in reqs]
    p50_paged, p99_paged = intertoken_ms(reqs)

    reqs = requests(prompts)
    t0 = time.perf_counter()
    overlapped.serve(reqs, arrival_steps=arrivals)
    dt_ov = time.perf_counter() - t0
    tok_ov = sum(len(r.output) for r in reqs)
    assert [r.output for r in reqs] == paged_out, (
        "overlapped engine changed greedy outputs — threading must be a "
        "pure latency/throughput knob")
    p50_ov, p99_ov = intertoken_ms(reqs)

    tps_sync = tok_sync / dt_sync
    tps_paged = tok_paged / dt_paged
    tps_ov = tok_ov / dt_ov
    assert tps_ov >= tps_paged, (
        f"overlapped engine lost throughput: {tps_ov:.1f} vs "
        f"{tps_paged:.1f} tok/s on the same trace")
    assert p99_ov < p99_paged, (
        f"overlapped engine did not improve p99 inter-token latency: "
        f"{p99_ov:.1f} vs {p99_paged:.1f} ms")
    ost = overlapped.stats
    util = paged.stats["page_util_sum"] / max(paged.stats["steps"], 1)
    return [
        ("SERVE/paged_vs_sync/sync_tok_per_s", round(tps_sync, 1),
         f"{sync_slots} slots x {max_seq}-row cache; {tok_sync} tokens"),
        ("SERVE/paged_vs_sync/paged_tok_per_s", round(tps_paged, 1),
         f"{paged_slots} slots on {pool_pages}x{page_size}-token pool "
         f"(= sync HBM at 1/6 of slots*max_seq); {tok_paged} tokens"),
        ("SERVE/paged_vs_sync/speedup_x", round(tps_paged / tps_sync, 2),
         "paged advantage (acceptance floor 1.5)"),
        ("SERVE/paged_vs_sync/pool_util_mean", round(util, 3),
         "mean fraction of pages in use per decode step"),
        ("SERVE/paged_vs_sync/pool_util_peak",
         round(paged.stats["peak_pages_in_use"] / pool_pages, 3),
         f"peak {paged.stats['peak_pages_in_use']} of {pool_pages} pages"),
        ("SERVE/paged_vs_sync/preemptions", paged.stats["preemptions"],
         "evict+recompute events during the timed trace"),
        ("SERVE/paged_vs_sync/overlapped_tok_per_s", round(tps_ov, 1),
         f"engine on the same trace/pool; {tok_ov} tokens, "
         f"{ost['admit_grouped_rows']} rows in {ost['admit_groups']} "
         f"batched prefills (floor: sync paged {round(tps_paged, 1)})"),
        ("SERVE/paged_vs_sync/sync_p50_ms", round(p50_paged, 2),
         "median inter-token latency, sync paged server"),
        ("SERVE/paged_vs_sync/sync_p99_ms", round(p99_paged, 2),
         "p99 inter-token latency, sync paged server (prefill stalls "
         "live decodes)"),
        ("SERVE/paged_vs_sync/overlapped_p50_ms", round(p50_ov, 2),
         "median inter-token latency, overlapped engine"),
        ("SERVE/paged_vs_sync/overlapped_p99_ms", round(p99_ov, 2),
         f"p99 inter-token latency, overlapped engine "
         f"({p99_paged / max(p99_ov, 1e-9):.2f}x better than sync paged; "
         "must be strictly better)"),
    ]


def router_scaling(seed: int = 0, replica_counts=(1, 2, 4)):
    """Aggregate routed throughput vs replica count, one Poisson trace.

    The SAME request trace drains through a ``Router`` over 1, 2 and 4
    independent ``ContinuousServer`` replicas (each with its own page
    pool and slots over shared params; launch/router.py). Replication is
    host-level data parallelism — each replica's sub-trace runs on its
    own thread, overlapping wherever XLA releases the GIL — so the rows
    report *aggregate* tokens/s across the replica set. Outputs are
    asserted identical across all counts: routing must be a pure
    throughput knob (the token-identity contract tests/test_router.py
    pins per-request). On a CPU runner XLA already multithreads each
    replica's compute, so the scaling row understates what disjoint
    per-host device sets deliver; the row exists to track the trajectory
    of routing overhead, not to claim linear CPU speedups.
    """
    import time

    from repro.launch.router import Router, build_replicas
    from repro.launch.serve import Request

    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    n_req, max_new = 24, 12
    prompts = [rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
               for _ in range(n_req)]
    arrivals = np.sort(rng.poisson(0.8, size=n_req)).tolist()

    rows, base_out, tps_by_n = [], None, {}
    for n in replica_counts:
        replicas = build_replicas(model, params, n, num_slots=6,
                                  max_seq=64, page_size=8)
        for rep in replicas:
            rep.warmup(max_len=8 + max_new)
        router = Router(replicas)
        reqs = [Request(prompt=p, max_new_tokens=max_new) for p in prompts]
        t0 = time.perf_counter()
        router.serve(reqs, arrival_steps=arrivals)
        dt = time.perf_counter() - t0
        tok = sum(len(r.output) for r in reqs)
        out = [r.output for r in reqs]
        if base_out is None:
            base_out = out
        else:
            assert out == base_out, (
                f"routing over {n} replicas changed greedy outputs — "
                "assignment must be a pure throughput knob")
        tps_by_n[n] = tok / dt
        agg = router.aggregate_stats()
        rows.append((f"SERVE/router/replicas_{n}_tok_per_s",
                     round(tok / dt, 1),
                     f"{n} replica(s) x 6 slots, aggregate over {tok} "
                     f"tokens, {agg['preemptions']} preemptions"))
    lo, hi = min(replica_counts), max(replica_counts)
    rows.append((f"SERVE/router/scaling_x_{hi}v{lo}",
                 round(tps_by_n[hi] / tps_by_n[lo], 2),
                 f"aggregate tok/s at {hi} replicas over {lo} (CPU "
                 "runner: replicas contend for the same cores)"))
    return rows


def spec_decode_comparison(seed: int = 0, ks=(2, 4, 8)):
    """Barycenter-draft speculative decoding vs plain decode (DESIGN.md §12).

    The same Poisson request trace drains through ContinuousServer at
    spec_k in {0} + ks on the Mixtral-shape SVD store (fused_kernel
    verifier). Greedy outputs are asserted token-identical to the
    spec_k=0 run — spec is a pure latency knob — and each k reports

      * ``k{k}_accepted_tok_per_step``: mean tokens a slot emits per spec
        round (1 bonus token + accepted drafts). The acceptance bar is
        > 1 — the drafter must actually land drafts, otherwise every
        round degenerates to a more expensive decode step;
      * ``k{k}_tok_per_s`` with the speedup over plain decode in the
        derived column. CPU wall-clock is a proxy: each draft step still
        runs full model depth here, so the tokens/s headline understates
        an accelerator, where the center-only FFN (no u/v gathers, no
        dispatch) is the cheap part by construction.

    The config keeps max_seq comfortably above prompt+budget so the
    round size never shrinks below spec_k — that makes
    ``spec_drafted / (k-1)`` an exact slot-round count, which turns the
    accepted counter into the per-step acceptance metric without a
    dedicated stat. Compilation is excluded via warmup().
    """
    import time

    from repro.launch.serve import ContinuousServer, Request

    rng = np.random.default_rng(seed)
    num_slots, max_seq, page_size, max_new = 2, 64, 8, 24
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                        keep_ratio=0.5),
        # the k=8 verify forward carries num_slots*k = 16 tokens; widen the
        # ragged per-token threshold so verify and plain decode share one
        # MoE path and greedy argmax stays bitwise-identical (DESIGN.md §12)
        moe=dataclasses.replace(cfg.moe,
                                token_path_max_tokens=num_slots * max(ks)))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(8)]
    arrivals = np.sort(rng.poisson(0.5, size=len(prompts))).tolist()

    rows = []
    plain_out = None
    tps_plain = None
    for k in (0,) + tuple(ks):
        srv = ContinuousServer(model, cp, num_slots=num_slots,
                               max_seq=max_seq, page_size=page_size,
                               apply_mode="fused_kernel", spec_k=k)
        srv.warmup(max_len=6 + max_new)
        reqs = [Request(prompt=p, max_new_tokens=max_new) for p in prompts]
        t0 = time.perf_counter()
        srv.serve(reqs, arrival_steps=arrivals)
        dt = time.perf_counter() - t0
        tok = sum(len(r.output) for r in reqs)
        tps = tok / dt
        outs = [r.output for r in reqs]
        if k == 0:
            plain_out, tps_plain = outs, tps
            rows.append(("SERVE/spec/plain_tok_per_s", round(tps, 1),
                         f"{tok} tokens, {srv.stats['steps']} steps"))
            continue
        assert outs == plain_out, (
            f"spec_k={k} changed greedy outputs — speculation must be a "
            "pure latency knob")
        st = srv.stats
        slot_rounds = st["spec_drafted"] // (k - 1)
        acc_per_step = 1 + st["spec_accepted"] / max(slot_rounds, 1)
        assert acc_per_step > 1.0, (
            f"spec_k={k}: no drafts accepted ({st}) — the barycenter "
            "center stopped tracking the experts on the Mixtral-shape "
            "config")
        rows.append((f"SERVE/spec/k{k}_accepted_tok_per_step",
                     round(acc_per_step, 2),
                     f"rounds={st['spec_rounds']} "
                     f"drafted={st['spec_drafted']} "
                     f"accepted={st['spec_accepted']} (floor 1.0)"))
        rows.append((f"SERVE/spec/k{k}_tok_per_s", round(tps, 1),
                     f"speedup_x={tps / tps_plain:.2f} vs plain"))
    return rows


def zoo_decode_serving(seed: int = 0):
    """Decode throughput of ContinuousServer per mixer family.

    One row per architecture family the StatePage layer serves: pure
    attention (token pages), hybrid rec-rec-attn (pages + state slots) and
    pure recurrence (state slots only). Same trace shape for all three —
    16 decode-heavy requests on a fully provisioned pool, so the numbers
    track per-step model cost + scheduling overhead, not preemption luck.
    Compilation is excluded by a one-request warm serve (all prompts share
    one length, so the timed trace replays already-traced shapes)."""
    import time

    from repro.launch.serve import ContinuousServer, Request

    rng = np.random.default_rng(seed)
    rows = []
    for arch in ("granite-8b", "recurrentgemma-9b", "rwkv6-1.6b"):
        cfg = reduced_config(arch)
        model = build_model(cfg)
        params, _ = model.init_split(jax.random.PRNGKey(0))
        server = ContinuousServer(model, params, num_slots=8, max_seq=64,
                                  page_size=8)
        prompts = [rng.integers(0, cfg.vocab_size, size=(8,))
                   .astype(np.int32) for _ in range(16)]
        server.serve([Request(prompt=prompts[0], max_new_tokens=2)])
        reqs = [Request(prompt=p, max_new_tokens=24) for p in prompts]
        t0 = time.perf_counter()
        server.serve(reqs)
        dt = time.perf_counter() - t0
        tok = sum(len(r.output) for r in reqs)
        rows.append((f"SERVE/zoo/{arch}/tok_per_s", round(tok / dt, 1),
                     f"8 slots; {server.state.describe()}"))
    return rows


def serve_suite(seed: int = 0):
    """All serving rows: the paged-vs-sync headline, the zoo matrix, and
    routed throughput vs replica count."""
    return (paged_vs_sync_serving(seed) + zoo_decode_serving(seed)
            + router_scaling(seed))


def grouped_roofline_mixtral(e=8, c=128, d=4096, f=14336, keep=0.25,
                             bm=128, bn=128, dtype_bytes=4):
    """Analytic TPU roofline at true Mixtral-8x7B expert shapes.

    HBM bytes + FLOPs for one expert-FFN segment ([d, f], all E experts at
    capacity C), per forward path:

      * restored — write then read the restored bank E*d*f (the in-graph
        `_restored_bank` materialization) on top of the restore einsum.
      * grouped  — the Pallas kernel never materializes the bank. Center
        traffic is derived from the kernel's OWN block picker: with a
        single k block the center tile is reused across the expert grid
        axis (read once per (m, n) tile); when the contraction doesn't fit
        VMEM (it doesn't at f32 Mixtral shapes) the k loop re-streams the
        center once per expert pass, and the model charges the full E x.

    The grouped kernel beating restored here is the paper's "restore for
    free" claim stated in bytes.
    """
    from repro.kernels.resmoe_grouped import _pick_bk

    r = int(keep * d * f / (d + f))  # svd_rank_for_ratio's budget rule
    rp = r + ((-r) % 128)
    flops_base = 2 * e * c * d * f
    rows = []

    restore_flops = 2 * e * d * r * f  # u @ v per expert
    bank_bytes = e * d * f * dtype_bytes
    restored_bytes = (
        2 * bank_bytes  # write the restored bank, read it back for the matmul
        + (d * f + e * (d + f) * r) * dtype_bytes  # center + factors
        + 2 * e * c * (d + f) * dtype_bytes  # activations in/out
    )
    rows.append(("T11/roofline_mixtral/restored_GB",
                 round(restored_bytes / 1e9, 3),
                 f"flops={flops_base + restore_flops:.3e}"))

    n_tiles_m = -(-c // bm)
    kp = d + ((-d) % 128)
    n_k = -(-kp // _pick_bk(kp, min(bm, c), bn, rp, dtype_bytes))
    center_passes = 1 if n_k == 1 else e  # single k block => reuse across E
    grouped_bytes = (
        n_tiles_m * center_passes * d * f * dtype_bytes
        + e * (d + f) * r * dtype_bytes  # per-expert factors, once
        + 2 * e * c * (d + f) * dtype_bytes  # activations in/out
    )
    lowrank_flops = 2 * e * c * r * (d + f)
    rows.append(("T11/roofline_mixtral/grouped_kernel_GB",
                 round(grouped_bytes / 1e9, 3),
                 f"flops={flops_base + lowrank_flops:.3e} "
                 f"n_k={n_k} center_passes={center_passes}"))
    rows.append(("T11/roofline_mixtral/grouped_vs_restored_bytes_x",
                 round(restored_bytes / grouped_bytes, 2),
                 "grouped kernel advantage (>1 = grouped wins)"))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
