"""Paper Table 4: the center ablation — no-center vs Avg vs Git vs WB.

Reported as approximation error (the paper reports downstream accuracy; the
downstream analog lives in downstream_eval.py)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.baselines import run_baseline
from repro.core.compress import compress_bank, design_matrices

from .common import trained_like_bank


def run(keep_ratio: float = 0.25, seed: int = 0):
    rng = np.random.default_rng(seed)
    bank = trained_like_bank(rng, n_experts=8, d=64, f=224, glu=True)
    design = design_matrices(bank)
    rows = []
    for label, fn in [
        ("UP(no center)", lambda: run_baseline("up", design, keep_ratio)),
        ("Avg+UP", lambda: compress_bank(bank, "up", keep_ratio, center="avg")),
        ("Git+UP", lambda: compress_bank(bank, "up", keep_ratio, center="git")),
        ("WB+UP", lambda: compress_bank(bank, "up", keep_ratio, center="wb")),
        ("SVD(no center)", lambda: run_baseline("svd", design, keep_ratio)),
        ("WB+SVD", lambda: compress_bank(bank, "svd", keep_ratio, center="wb")),
    ]:
        t0 = time.perf_counter()
        res = fn()
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"T4/{label}", round(us, 1),
                     round(res.approximation_error(design), 4)))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
