# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator.

Tables map 1:1 to the paper (see DESIGN.md §8):
  approx_error     -> Table 1      ablation_center -> Table 4
  downstream_eval  -> Tables 2/3/7 rate_sweep      -> Figure 4
  memory           -> Table 10     runtime         -> Table 11
  flops_table      -> Table 12     roofline        -> §4.3 cost model sweep

Run: PYTHONPATH=src python -m benchmarks.run [--only t1,t4,...] [--fast]
             [--json BENCH.json]

Exit code is the CI contract (scripts/ci.sh bench): any suite that raises
makes the run exit nonzero, so the bench tier can gate a PR instead of
silently printing partial rows. ``--json`` additionally writes a
machine-readable ``{row_name: {value, derived}}`` map of every emitted CSV row — the
per-PR perf-trajectory artifact the workflow uploads.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def row_to_json(row) -> dict:
    """One CSV row -> the artifact's ``{value, derived}`` entry.

    The value field must be the row's NUMBER: suites that historically
    stuffed their metric into the derived column with a 0 value column
    (memory/flops analytic tables) get it promoted here, keeping the
    original derived string as provenance — downstream trajectory
    tooling reads ``value`` and must never have to parse ``derived``.
    """
    value = row[1]
    derived = str(row[2]) if len(row) > 2 else ""
    if not value and derived:
        try:
            value = float(derived)
        except ValueError:
            pass
    return {"value": value, "derived": derived}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: t1,t3,t4,f4,t10,t11,t12,serve,spec,"
                         "roofline,frontier,xl")
    ap.add_argument("--fast", action="store_true",
                    help="skip the training-backed downstream eval")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a {row_name: {value, derived}} JSON map of "
                         "the emitted rows (the bench-trajectory artifact; "
                         "`value` is always the row's numeric metric, "
                         "`derived` is provenance text)")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    from . import (ablation_center, approx_error, flops_table, frontier,
                   memory, rate_sweep, runtime)
    from .roofline import analyze

    suites = [
        ("t1", approx_error.run),
        ("t4", ablation_center.run),
        ("f4", rate_sweep.run),
        ("t10", memory.run),
        ("t11", runtime.run),
        ("t12", flops_table.run),
        ("serve", runtime.serve_suite),
        ("spec", runtime.spec_decode_comparison),
        ("roofline", analyze.run),
        ("frontier", frontier.run),
    ]
    if not args.fast:
        from . import cross_layer, downstream_eval

        suites.insert(1, ("t3", downstream_eval.run))
        suites.append(("xl", cross_layer.run))

    # validate against the suites THIS invocation can run: under --fast,
    # t3/xl are absent, and silently matching nothing would exit 0 with an
    # empty run — exactly the false green the exit-code contract forbids
    known = {key for key, _ in suites}
    if want and want - known:
        print(f"unknown suite keys for this invocation: "
              f"{sorted(want - known)}; available: {sorted(known)}",
              file=sys.stderr)
        return 2

    print("name,us_per_call,derived")
    values = {}
    failed = []
    for key, fn in suites:
        if want and key not in want:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(",".join(str(x) for x in row))
                values[str(row[0])] = row_to_json(row)
            print(f"# suite {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failed.append(key)
            print(f"# suite {key} FAILED", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        # write even on partial failure: the trajectory keeps whatever rows
        # DID emit, while the exit code still fails the tier
        with open(args.json, "w") as fh:
            json.dump(values, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {len(values)} rows -> {args.json}", file=sys.stderr)
    if failed:
        print(f"# FAILED suites: {','.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
