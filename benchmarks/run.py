# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator.

Tables map 1:1 to the paper (see DESIGN.md §8):
  approx_error     -> Table 1      ablation_center -> Table 4
  downstream_eval  -> Tables 2/3/7 rate_sweep      -> Figure 4
  memory           -> Table 10     runtime         -> Table 11
  flops_table      -> Table 12     roofline        -> §4.3 cost model sweep

Run: PYTHONPATH=src python -m benchmarks.run [--only t1,t4,...] [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: t1,t3,t4,f4,t10,t11,t12,roofline")
    ap.add_argument("--fast", action="store_true",
                    help="skip the training-backed downstream eval")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    from . import (ablation_center, approx_error, flops_table, memory,
                   rate_sweep, runtime)
    from .roofline import analyze

    suites = [
        ("t1", approx_error.run),
        ("t4", ablation_center.run),
        ("f4", rate_sweep.run),
        ("t10", memory.run),
        ("t11", runtime.run),
        ("t12", flops_table.run),
        ("roofline", analyze.run),
    ]
    if not args.fast:
        from . import cross_layer, downstream_eval

        suites.insert(1, ("t3", downstream_eval.run))
        suites.append(("xl", cross_layer.run))

    print("name,us_per_call,derived")
    failures = 0
    for key, fn in suites:
        if want and key not in want:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(",".join(str(x) for x in row))
            print(f"# suite {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# suite {key} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
