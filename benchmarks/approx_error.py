"""Paper Table 1: approximation error across compression methods.

Scaled-down geometry of the paper's two settings:
  * switch-like:  relu non-GLU experts  (p_I = 4p)
  * mixtral-like: SwiGLU experts        (p_I = 3.5p)

Error metric is exactly §5.2: mean_k ||T_k W_k - \\hat W_k||_F^2 / p_I.
The expected ordering (paper): ResMoE(UP) < UP < ... and ResMoE(SVD) < SVD.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.baselines import run_baseline
from repro.core.compress import compress_bank, design_matrices

from .common import trained_like_bank


def run(keep_ratio: float = 0.25, seed: int = 0, verbose: bool = True):
    rng = np.random.default_rng(seed)
    settings = {
        "switch-like": dict(n_experts=8, d=32, f=128, glu=False),
        "mixtral-like": dict(n_experts=8, d=64, f=224, glu=True),
    }
    rows = []
    for name, kw in settings.items():
        bank = trained_like_bank(rng, **kw)
        design = design_matrices(bank)
        for meth in ("up", "wanda", "sp", "svd", "msmoe", "git", "meo",
                     "mlp_fusion"):
            t0 = time.perf_counter()
            res = run_baseline(meth, design, keep_ratio)
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"T1/{name}/{res.name}", round(us, 1),
                         round(res.approximation_error(design), 4)))
        for meth in ("up", "svd", "block"):
            t0 = time.perf_counter()
            comp = compress_bank(bank, method=meth, keep_ratio=keep_ratio)
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"T1/{name}/ResMoE({meth.upper()})", round(us, 1),
                         round(comp.approximation_error(design), 4)))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
