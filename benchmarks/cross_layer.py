"""Beyond-paper study: ResMoE with scope="cross_layer" on DENSE models.

The published method needs an expert *population*; dense models have one
FFN per layer. The extension treats the L per-layer FFNs as the population:
barycenter across layers, residual per layer. This is the natural port of
ResMoE to 8/10 assigned architectures (DESIGN.md §7).

Protocol: train a reduced dense LM, compress {all layer FFNs} with
(a) cross-layer ResMoE(UP), (b) direct per-layer UP at the same budget,
evaluate zero-shot NLL. Storage accounting includes the shared center.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core.baselines import run_baseline
from repro.core.compress import compress_bank, design_matrices, restored_bank
from repro.data import make_pipeline
from repro.launch.train import run_training
from repro.models import build_model


def _eval_nll(model, params, pipe, steps=3):
    fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
    tot = 0.0
    for i in range(7000, 7000 + steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        logits = fwd(params, batch).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][..., None], -1)[..., 0]
        tot += float((lse - gold).mean())
    return tot / steps


def run(steps: int = 120, keep: float = 0.5, seed: int = 0):
    out = run_training("granite-8b", steps=steps, seq_len=64, global_batch=4,
                       lr=3e-3, seed=seed, log_every=60)
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params = out["params"]
    pipe = make_pipeline(cfg, 64, 4, seed=seed)
    rows = []
    base_nll = _eval_nll(model, params, pipe)
    rows.append(("XL/dense", 0, f"nll={base_nll:.4f}"))

    # the layer-FFN "bank": stacked dense FFNs [L, d, ff]
    p = jax.tree_util.tree_map(lambda x: np.array(x, copy=True), params)
    ffn = p["segments"][0]["slots"][0]["ffn"]
    bank = {k: np.asarray(v) for k, v in ffn.items()}  # w1/w3: [L,d,f], w2: [L,f,d]
    design = design_matrices(bank)
    dense_params = sum(v.size for v in bank.values())

    # (a) cross-layer ResMoE(UP)
    comp = compress_bank(bank, method="up", keep_ratio=keep)
    rb = restored_bank(comp, {k: v[0] for k, v in bank.items()})
    pa = jax.tree_util.tree_map(lambda x: np.array(x, copy=True), params)
    for k in ("w1", "w2", "w3"):
        pa["segments"][0]["slots"][0]["ffn"][k] = rb[k].astype(np.float32)
    nll_a = _eval_nll(model, pa, pipe)
    stored = comp.num_params()
    rows.append((f"XL/ResMoE-crosslayer(UP)@{keep}", 0,
                 f"nll={nll_a:.4f};params={stored/dense_params:.2f}x"))

    # (b) direct per-layer UP at matched TOTAL budget (center amortized)
    match_ratio = min(1.0, stored / dense_params)
    direct = run_baseline("up", design, match_ratio)
    pb = jax.tree_util.tree_map(lambda x: np.array(x, copy=True), params)
    from repro.core.compress import split_design

    for li in range(design.shape[0]):
        w = split_design(direct.approx[li], {k: v[0] for k, v in bank.items()})
        for k in w:
            pb["segments"][0]["slots"][0]["ffn"][k][li] = w[k]
    nll_b = _eval_nll(model, pb, pipe)
    rows.append((f"XL/direct-UP@{match_ratio:.2f}", 0, f"nll={nll_b:.4f}"))
    rows.append(("XL/advantage", 0,
                 f"resmoe_delta={nll_a-base_nll:+.4f};direct_delta={nll_b-base_nll:+.4f}"))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
