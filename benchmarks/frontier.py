"""Store-bytes vs quality frontier for per-layer compression plans.

Trains the reduced Mixtral on the synthetic LM stream, then sweeps the
uniform (rank, store dtype) grid and the byte-budget plan search
(core/plan.py::solve_plan) over the SAME per-layer candidate scores.
Each row carries ``bytes=<factor store bytes>;err=<summed per-layer
approximation error>;nll=<held-out NLL of the compressed model>`` so the
BENCH_<n>.json trajectory records the whole frontier curve.

The budget plan is solved at the byte budget of the best uniform setting
and seeded FROM that setting, so it must weakly Pareto-dominate it:
no more bytes, no more error. That is asserted here — a regression in
solve_plan (accepting error-increasing moves, mispricing bytes) fails
the bench tier, not just a curve eyeball.
"""
from __future__ import annotations

import dataclasses

from repro.configs import reduced_config
from repro.core.plan import CompressionPlan, LayerRecipe, layer_candidates, solve_plan
from repro.data import make_pipeline
from repro.launch.train import run_training
from repro.models import build_model, compress_model_params
from repro.models import transformer as tfm
from repro.models.model import _EXPERT_KEYS, _unstack_segments

RANKS = (6, 12, 24)
DTYPES = ("fp32", "int8")


def _layer_banks(params, cfg):
    import jax
    import numpy as np

    params = jax.tree_util.tree_map(np.asarray, params)
    flat = _unstack_segments(params["segments"], tfm.build_plan(cfg))
    specs = tfm.layer_specs(cfg)
    banks = []
    for i, spec in enumerate(specs):
        if spec.ffn != "moe":
            continue
        ffn = flat[i]["ffn"]
        banks.append((i, {k: ffn[k] for k in _EXPERT_KEYS if k in ffn}))
    return banks


def run(steps: int = 60, seed: int = 0):
    from .downstream_eval import _eval

    out = run_training("mixtral-8x7b", steps=steps, seq_len=64,
                       global_batch=4, lr=3e-3, seed=seed, log_every=50)
    cfg = reduced_config("mixtral-8x7b")
    params = out["params"]
    pipe = make_pipeline(cfg, 64, 4, seed=seed)
    model = build_model(cfg)

    banks = _layer_banks(params, cfg)
    cands = [layer_candidates(bank, RANKS, dtypes=DTYPES, seed=i)
             for i, bank in banks]
    moe_idx = [i for i, _ in banks]

    def _compressed_nll(plan):
        recipes = [LayerRecipe() for _ in range(cfg.num_layers)]
        for i, rec in zip(moe_idx, plan):
            recipes[i] = rec
        pcfg = dataclasses.replace(cfg, resmoe=dataclasses.replace(
            cfg.resmoe, enabled=True, method="svd", apply_mode="fused",
            plan=CompressionPlan(tuple(recipes))))
        cp, _ = compress_model_params(params, pcfg)
        pmodel = build_model(pcfg)
        nll, _acc = _eval(pmodel, cp, pipe, apply_mode="fused")
        return nll

    rows = []
    uniform = {}
    for r in RANKS:
        for dt in DTYPES:
            want = LayerRecipe(rank=r, store_dtype=dt)
            idx, chosen = [], []
            for layer in cands:
                j = next(k for k, c in enumerate(layer)
                         if c.recipe == want)
                idx.append(j)
                chosen.append(layer[j])
            size = sum(c.bytes for c in chosen)
            err = sum(c.error for c in chosen)
            nll = _compressed_nll([c.recipe for c in chosen])
            uniform[(r, dt)] = (idx, size, err, nll)
            rows.append((f"FRONTIER/uniform-r{r}-{dt}", 0,
                         f"bytes={size};err={err:.6f};nll={nll:.4f}"))

    # budget plan at a mid-grid byte budget: the best uniform setting
    # that FITS the budget is the baseline, and the search is seeded
    # from it so dominance cannot regress to chance
    budget = uniform[(RANKS[len(RANKS) // 2], "fp32")][1]
    best_key = min((k for k in uniform if uniform[k][1] <= budget),
                   key=lambda k: uniform[k][2])
    start, _size_best, err_best, _nll_best = uniform[best_key]
    chosen = solve_plan(cands, budget, start=start)
    plan_bytes = sum(c.bytes for c in chosen)
    plan_err = sum(c.error for c in chosen)
    plan_nll = _compressed_nll([c.recipe for c in chosen])
    rows.append((f"FRONTIER/plan@{budget}", 0,
                 f"bytes={plan_bytes};err={plan_err:.6f};nll={plan_nll:.4f}"))

    # Pareto-dominance of the budget search over the best uniform point
    # (weak on both axes by construction — seeded from it, moves only
    # accepted when error strictly drops and bytes stay under budget)
    assert plan_bytes <= budget, (plan_bytes, budget)
    assert plan_err <= err_best + 1e-12, (plan_err, err_best)
    rows.append((
        "FRONTIER/dominates",
        0,
        f"budget={budget}: plan(bytes={plan_bytes},err={plan_err:.6f}) vs "
        f"best fitting uniform r{best_key[0]}-{best_key[1]}"
        f"(bytes={_size_best},err={err_best:.6f})",
    ))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
