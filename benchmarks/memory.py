"""Paper Table 10: memory of one MoE layer per method (MB).

Two panels: analytic numbers at the REAL model geometry (Mixtral 8x14336,
DeepSeekMoE 64x 688/1408-style), and measured store sizes from our
implementation at reduced geometry.  Our TPU "block" store is added — it
fixes the COO-index blow-up the paper laments in Appendix A.7.
"""
from __future__ import annotations

import numpy as np

from repro.core.compress import compress_bank
from repro.core.residual import svd_rank_for_ratio

from .common import trained_like_bank


def analytic_layer_mb(n_experts: int, d: int, f: int, n_mats: int,
                      keep: float = 0.25) -> dict:
    dense = n_experts * n_mats * d * f * 2 / 2**20  # bf16
    per_expert = n_mats * d * f
    out = {"Full": dense}
    # UP stored dense-with-zeros (paper's Table 11 runtime setting) or COO
    out["UP(COO int64)"] = n_experts * (keep * per_expert * (2 + 8)) / 2**20
    out["UP(CSR int32)"] = n_experts * (keep * per_expert * (2 + 4)) / 2**20
    out["SP"] = dense * keep
    r = svd_rank_for_ratio(f, n_mats * d, keep)
    out["SVD"] = n_experts * r * (f + n_mats * d) * 2 / 2**20
    out["Merge(8->2)"] = dense / 4
    center = per_expert * 2 / 2**20
    out["ResMoE(UP,CSR)"] = center + out["UP(CSR int32)"]
    out["ResMoE(SVD)"] = center + out["SVD"]
    # block store: +8B per 8x128 block of index overhead
    nblocks = keep * per_expert / (8 * 128)
    out["ResMoE(block)"] = center + (
        n_experts * (keep * per_expert * 2 + nblocks * 8) / 2**20
    )
    return out


def run(seed: int = 0):
    rows = []
    for name, (e, d, f, m) in {
        "mixtral": (8, 4096, 14336, 3),
        "deepseekmoe": (64, 2048, 1408, 3),
    }.items():
        for meth, mb in analytic_layer_mb(e, d, f, m).items():
            rows.append((f"T10/{name}/{meth}", 0, round(mb, 1)))
    # measured (reduced geometry)
    rng = np.random.default_rng(seed)
    bank = trained_like_bank(rng, n_experts=8, d=64, f=224, glu=True)
    dense_bytes = sum(v.size * 2 for v in bank.values())
    rows.append(("T10/measured/Full", 0, dense_bytes))
    for meth in ("up", "svd", "block"):
        comp = compress_bank(bank, method=meth, keep_ratio=0.25)
        rows.append((f"T10/measured/ResMoE({meth})", 0, comp.storage_bytes(2)))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
