"""Compress tier: per-layer-plan stores booted from disk serve identically.

Acceptance differential for the plan PR: a trimmed + mixed-rank +
mixed-dtype store persisted with ``save_compressed_store`` and booted
back from disk must serve token-identically (greedy) to the in-memory
compressed tree — through the paged ``ContinuousServer`` AND the
``OverlappedServer``, under forced preemption, at spec_k 0 and 2. The
CLI roundtrips (uniform fp32, uniform int8, per-layer ``--plan``,
``--byte-budget``) run ``repro.launch.serve`` as a subprocess twice per
setting — compress+persist then boot-from-disk — and diff the decoded
outputs.

Runs in its own CI tier (``scripts/ci.sh compress``); excluded from
tier-1 via the ``compress`` marker.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    load_compressed_store,
    save_compressed_store,
    validate_store_meta,
)
from repro.configs import reduced_config
from repro.core.plan import CompressionPlan, LayerRecipe
from repro.launch.engine import OverlappedServer
from repro.launch.serve import ContinuousServer, Request, Server
from repro.models import build_model, compress_model_params
from repro.sharding import split_logical

pytestmark = pytest.mark.compress

# One recipe per reduced-mixtral layer: expert trim + rank override,
# int8, and a plain rank override — every heterogeneity axis at once.
MIXED_PLAN = CompressionPlan((
    LayerRecipe(rank=6, drop_experts=(1, 5)),
    LayerRecipe(rank=24, store_dtype="int8"),
    LayerRecipe(rank=12),
))


def _planned_cfg(plan, apply_mode="fused"):
    cfg = reduced_config("mixtral-8x7b")
    rc = dataclasses.replace(cfg.resmoe, enabled=True, method="svd",
                             apply_mode=apply_mode, plan=plan)
    return dataclasses.replace(cfg, resmoe=rc)


@pytest.fixture(scope="module")
def planned_store(tmp_path_factory):
    """(cfg, model, in-memory store, disk-loaded store) for MIXED_PLAN."""
    cfg = _planned_cfg(MIXED_PLAN)
    base = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, plan=None))
    dense, _ = split_logical(build_model(base).init(jax.random.PRNGKey(0)))
    comp, _ = compress_model_params(dense, cfg)
    store_dir = str(tmp_path_factory.mktemp("planned_store"))
    save_compressed_store(store_dir, comp, meta={
        "arch": cfg.name, "method": "svd", "num_experts":
        cfg.moe.num_experts, "d_model": cfg.d_model,
        "plan": MIXED_PLAN.to_json(),
    })
    loaded, meta = load_compressed_store(store_dir)
    validate_store_meta(meta, cfg)
    assert CompressionPlan.from_json(meta["plan"]) == MIXED_PLAN
    model = build_model(cfg)
    comp = jax.tree_util.tree_map(jnp.asarray, comp)
    return cfg, model, comp, loaded


def _schedule(seed, vocab, n=4):
    r = np.random.default_rng(seed)
    prompts = [r.integers(0, vocab, size=(int(r.choice([4, 6, 8])),))
               .astype(np.int32) for _ in range(n)]
    max_new = [int(r.integers(3, 7)) for _ in range(n)]
    order = r.permutation(n)
    arrivals = np.sort(r.poisson(1.0, size=n)).tolist()
    return prompts, max_new, order, arrivals


def _disk_vs_memory(planned_store, make_server, spec_k, seeds=(0, 1, 2)):
    """Sync oracle on the in-memory tree vs ``make_server`` on the
    disk-loaded tree — greedy outputs must match token for token."""
    cfg, model, comp, loaded = planned_store
    sync = Server(model, comp, num_slots=3, max_seq=48, apply_mode="fused")
    booted = make_server(model, loaded, spec_k)
    for seed in seeds:
        prompts, max_new, order, arrivals = _schedule(seed, cfg.vocab_size)
        ra = [Request(prompt=p, max_new_tokens=m)
              for p, m in zip(prompts, max_new)]
        rb = [Request(prompt=p, max_new_tokens=m)
              for p, m in zip(prompts, max_new)]
        sync.serve(ra)
        booted.serve([rb[i] for i in order], arrival_steps=arrivals)
        for i, (a, b) in enumerate(zip(ra, rb)):
            assert a.output == b.output, (seed, i, a.output, b.output)
        if booted.pool is not None:
            booted.pool.check()
            assert booted.pool.pages_in_use == 0
        booted.state.check()
    return booted.stats


@pytest.mark.parametrize("spec_k", [0, 2])
def test_disk_boot_continuous_differential(planned_store, spec_k):
    """ContinuousServer on the disk-booted heterogeneous store == sync
    oracle on the in-memory tree, with a forced eviction."""
    stats = _disk_vs_memory(
        planned_store,
        lambda model, params, k: ContinuousServer(
            model, params, num_slots=3, max_seq=48, page_size=4,
            pool_pages=9, apply_mode="fused", preempt_steps=[1],
            spec_k=k),
        spec_k)
    assert stats["preemptions"] >= 1, "forced preemption must have fired"


@pytest.mark.parametrize("spec_k", [0, 2])
def test_disk_boot_overlapped_differential(planned_store, spec_k):
    """OverlappedServer (background admission/detokenize threads) on the
    disk-booted store == sync oracle, with a forced eviction."""
    stats = _disk_vs_memory(
        planned_store,
        lambda model, params, k: OverlappedServer(
            model, params, num_slots=3, max_seq=48, page_size=4,
            pool_pages=9, apply_mode="fused", preempt_steps=[1],
            spec_k=k, admit_batch=2),
        spec_k)
    assert stats["preemptions"] >= 1, "forced preemption must have fired"


# ---------------------------------------------------------------------------
# CLI roundtrips (compress+persist, then boot-from-disk; outputs diffed)
# ---------------------------------------------------------------------------


def _run_serve(args, cwd):
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--requests", "3",
         "--max-new", "6", *args],
        capture_output=True, text=True, env=env, cwd=cwd, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return [ln for ln in out.stdout.splitlines() if ln.startswith("req")]


def _roundtrip(tmp_path, extra, boot_extra=()):
    store = str(tmp_path / "store")
    first = _run_serve(["--apply-mode", "fused", "--store-dir", store,
                        *extra], str(tmp_path))
    again = _run_serve(["--apply-mode", "fused", "--store-dir", store,
                        *boot_extra], str(tmp_path))
    assert first and first == again, (first, again)


def test_cli_roundtrip_uniform_fp32(tmp_path):
    _roundtrip(tmp_path, [])


def test_cli_roundtrip_uniform_int8(tmp_path):
    # uniform dtypes are config-driven, so the boot repeats the flag
    # (only per-layer plans are persisted and therefore flag-free)
    _roundtrip(tmp_path, ["--store-dtype", "int8"],
               boot_extra=["--store-dtype", "int8"])


def test_cli_roundtrip_per_layer_plan(tmp_path):
    plan_file = tmp_path / "plan.json"
    plan_file.write_text(json.dumps(MIXED_PLAN.to_json()))
    # the persisted plan must make the re-boot flag-free
    _roundtrip(tmp_path, ["--plan", str(plan_file), "--paged",
                          "--overlapped", "--spec-k", "2"],
               boot_extra=["--paged", "--overlapped", "--spec-k", "2"])


def test_cli_roundtrip_byte_budget(tmp_path):
    _roundtrip(tmp_path, ["--byte-budget", "900000"])
