"""Int8-quantized compressed store: error bound, kernel parity, serving.

Parity coverage declared for scripts/check_parity_matrix.py:
# PARITY: restored/int8
# PARITY: fused/int8
# PARITY: fused_shared/int8
# PARITY: fused_kernel/int8
# PARITY: fused_token/int8
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.quant import (
    dequantize_int8,
    dequantize_store,
    int8_error_bound,
    is_quantized_store,
    quantize_int8,
    quantize_store,
)
from repro.launch.serve import Request, Server
from repro.models import (
    build_model,
    compress_model_params,
    quantize_compressed_params,
)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _compressed_pair(arch="mixtral-8x7b", keep=0.5, seed=0, **moe_kw):
    """(cfg, model, fp32 store params, int8 store params)."""
    cfg = reduced_config(arch)
    moe = dataclasses.replace(cfg.moe, **moe_kw) if moe_kw else cfg.moe
    cfg = dataclasses.replace(
        cfg, moe=moe,
        resmoe=dataclasses.replace(cfg.resmoe, method="svd", keep_ratio=keep))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(seed))
    cp, _ = compress_model_params(params, cfg)
    return cfg, model, cp, quantize_compressed_params(cp)


# ---------------------------------------------------------------------------
# Quantize/dequantize primitives
# ---------------------------------------------------------------------------


def test_quant_roundtrip_error_bound_hypothesis():
    """Property: |x - dequant(quant(x))| <= scale/2 per channel, any shape,
    any reduction axis — the analytic bound of symmetric round-to-nearest."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        m=st.integers(1, 24),
        n=st.integers(1, 24),
        axis=st.integers(0, 1),
        scale_pow=st.integers(-12, 12),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=50, deadline=None)
    def check(m, n, axis, scale_pow, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(m, n)).astype(np.float32) * (2.0 ** scale_pow)
        q, s = quantize_int8(x, axis)
        assert q.dtype == np.int8
        back = np.asarray(dequantize_int8(q, s, axis))
        bound = np.expand_dims(int8_error_bound(s), axis)
        # tiny fp32 slack: the bound itself is computed in float
        assert np.all(np.abs(x - back) <= bound * (1 + 1e-5) + 1e-30)

    check()


def test_quant_roundtrip_error_bound_sweep(rng):
    """Deterministic bound check (runs even where hypothesis is absent):
    shapes, axes and magnitude scales swept explicitly."""
    for m, n, axis, pw in [(1, 1, 0, 0), (7, 13, 1, -8), (24, 3, 0, 10),
                           (5, 5, 1, 3), (2, 17, 0, -3), (16, 16, 1, 12)]:
        x = rng.normal(size=(m, n)).astype(np.float32) * (2.0 ** pw)
        q, s = quantize_int8(x, axis)
        back = np.asarray(dequantize_int8(q, s, axis))
        bound = np.expand_dims(int8_error_bound(s), axis)
        assert np.all(np.abs(x - back) <= bound * (1 + 1e-5) + 1e-30), (
            m, n, axis, pw)


def test_quant_zero_channel():
    """All-zero channels quantize to zeros with a finite positive scale."""
    x = np.zeros((4, 3), np.float32)
    x[:, 1] = 7.0
    q, s = quantize_int8(x, 0)
    assert np.all(np.isfinite(s)) and np.all(s > 0)
    back = np.asarray(dequantize_int8(q, s, 0))
    np.testing.assert_allclose(back[:, 0], 0.0)
    np.testing.assert_allclose(back[:, 1], 7.0, rtol=1e-2)


def test_quantize_store_roundtrip_shapes(rng):
    """Store quantization: int8 leaves + per-channel fp32 scales with the
    layout the kernels expect, and dequantize_store stays within bound."""
    e, d, f, r = 4, 16, 24, 5
    ffn = {
        "router": rng.normal(size=(d, e)).astype(np.float32),
        "center": {"w1": rng.normal(size=(d, f)).astype(np.float32),
                   "w2": rng.normal(size=(f, d)).astype(np.float32)},
        "u": rng.normal(size=(e, f, r)).astype(np.float32),
        "v": {"w1": rng.normal(size=(e, r, d)).astype(np.float32),
              "w2": rng.normal(size=(e, r, d)).astype(np.float32)},
    }
    q = quantize_store(ffn)
    assert is_quantized_store(q) and not is_quantized_store(ffn)
    assert q["center"]["w1"].dtype == np.int8
    assert q["center_scale"]["w1"].shape == (f,)
    assert q["center_scale"]["w2"].shape == (d,)
    assert q["u_scale"].shape == (e, r)
    assert q["v_scale"]["w1"].shape == (e, r)
    assert q["router"] is ffn["router"]  # untouched
    deq = dequantize_store(q)
    for name, orig in (("w1", ffn["center"]["w1"]),):
        err = np.max(np.abs(np.asarray(deq["center"][name]) - orig))
        bound = float(np.max(int8_error_bound(q["center_scale"][name])))
        assert err <= bound * (1 + 1e-5)
    err_u = np.max(np.abs(np.asarray(deq["u"]) - ffn["u"]))
    assert err_u <= float(np.max(int8_error_bound(q["u_scale"]))) * (1 + 1e-5)


def test_quantize_rejects_delta_store():
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="up",
                                        keep_ratio=1.0))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    with pytest.raises(ValueError, match="svd"):
        quantize_compressed_params(cp)


# ---------------------------------------------------------------------------
# Kernel parity: dequant-fused int8 kernels vs fp32 oracles on the
# dequantized factors
# ---------------------------------------------------------------------------


def test_grouped_q8_kernel_matches_dequant_ref(rng):
    from repro.kernels import grouped_lowrank_matmul_q8
    from repro.kernels.ref import grouped_lowrank_matmul_ref

    e, c, d, f, r = 4, 24, 48, 80, 10
    xg = jnp.asarray(rng.normal(size=(e, c, d)), jnp.float32)
    wf = rng.normal(size=(d, f)).astype(np.float32)
    af = rng.normal(size=(e, d, r)).astype(np.float32)
    bf = rng.normal(size=(e, r, f)).astype(np.float32)
    wq, sw = quantize_int8(wf, -2)
    aq, sa = quantize_int8(af, -2)
    bq, sb = quantize_int8(bf, -1)
    got = grouped_lowrank_matmul_q8(
        xg, jnp.asarray(wq), jnp.asarray(sw), jnp.asarray(aq),
        jnp.asarray(bq), jnp.asarray(sa * sb))
    ref = grouped_lowrank_matmul_ref(
        xg, np.asarray(dequantize_int8(wq, sw, -2)),
        np.asarray(dequantize_int8(aq, sa, -2)),
        np.asarray(dequantize_int8(bq, sb, -1)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("glu,act", [(True, "silu"), (False, "relu")])
def test_token_q8_kernel_matches_dequant_ref(rng, glu, act):
    from repro.kernels import token_lowrank_moe_q8
    from repro.kernels.ref import token_lowrank_moe_ref

    t, k, e, d, f, r = 6, 2, 8, 48, 80, 10
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    gates = jnp.asarray(rng.random((t, k)), jnp.float32)
    names = ("w1", "w3") if glu else ("w1",)
    center = {n: rng.normal(size=(d, f)).astype(np.float32) for n in names}
    center["w2"] = rng.normal(size=(f, d)).astype(np.float32)
    uf = rng.normal(size=(e, f, r)).astype(np.float32)
    vf = {n: rng.normal(size=(e, r, d)).astype(np.float32)
          for n in names + ("w2",)}
    store = quantize_store({"center": center, "u": uf, "v": vf})
    got = token_lowrank_moe_q8(
        x, ids, gates,
        {n: jnp.asarray(a) for n, a in store["center"].items()},
        {n: jnp.asarray(a) for n, a in store["center_scale"].items()},
        jnp.asarray(store["u"]), jnp.asarray(store["u_scale"]),
        {n: jnp.asarray(a) for n, a in store["v"].items()},
        {n: jnp.asarray(a) for n, a in store["v_scale"].items()},
        activation=act)
    deq = dequantize_store(store)
    ref = token_lowrank_moe_ref(x, ids, gates, deq["center"], deq["u"],
                                deq["v"], activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# Model-level parity: every apply mode serves the int8 store
# ---------------------------------------------------------------------------


def test_int8_all_modes_agree_glu(rng):
    """All five apply modes produce the same logits on the SAME int8 store
    (GLU Mixtral config) — the dequant-fused kernels and the in-graph
    dequant paths compute identical math."""
    cfg, model, cp, qp = _compressed_pair(token_path_max_tokens=0,
                                          capacity_factor=8.0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)),
                                   jnp.int32)}
    outs = {}
    for mode in ("fused", "restored", "fused_shared", "fused_kernel",
                 "fused_token"):
        logits, _ = jax.jit(
            lambda p, b, m=mode: model.forward(p, b, apply_mode=m))(qp, batch)
        outs[mode] = np.asarray(logits, np.float32)
    for mode, got in outs.items():
        np.testing.assert_allclose(got, outs["fused"], rtol=1e-4, atol=1e-3,
                                   err_msg=mode)


def test_int8_all_modes_agree_nonglu(rng):
    """Same cross-mode agreement on a non-GLU store (switch-base-8)."""
    cfg, model, cp, qp = _compressed_pair("switch-base-8",
                                          token_path_max_tokens=0,
                                          capacity_factor=8.0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)),
                                   jnp.int32)}
    outs = {}
    for mode in ("fused", "fused_kernel", "fused_token"):
        logits, _ = jax.jit(
            lambda p, b, m=mode: model.forward(p, b, apply_mode=m))(qp, batch)
        outs[mode] = np.asarray(logits, np.float32)
    for mode, got in outs.items():
        np.testing.assert_allclose(got, outs["fused"], rtol=1e-4, atol=1e-3,
                                   err_msg=mode)


def test_int8_logits_close_to_fp32_store(rng):
    """The quantization error itself stays bounded at the logit level: the
    int8 store's fused logits track the fp32 store's."""
    cfg, model, cp, qp = _compressed_pair()
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)),
                                   jnp.int32)}
    ref, _ = jax.jit(
        lambda p, b: model.forward(p, b, apply_mode="fused"))(cp, batch)
    got, _ = jax.jit(
        lambda p, b: model.forward(p, b, apply_mode="fused"))(qp, batch)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < 0.5, err


def test_int8_generation_parity_acceptance(rng):
    """Acceptance: the int8 store serves generation-parity output — greedy
    tokens IDENTICAL to the fp32 store on the reduced Mixtral config —
    through the fused, fused_kernel, and fused_token serving paths."""
    cfg, model, cp, qp = _compressed_pair()
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(3)]

    def gen(p, mode):
        srv = Server(model, p, num_slots=2, max_seq=64, apply_mode=mode)
        reqs = [Request(prompt=pr, max_new_tokens=6) for pr in prompts]
        srv.serve(reqs)
        return [r.output for r in reqs]

    ref = gen(cp, "fused")
    for mode in ("fused", "fused_kernel", "fused_token"):
        got = gen(qp, mode)
        assert got == ref, (mode, got, ref)


def test_ep_int8_parity_forced_mesh():
    """Int8 store under expert parallelism on a forced 8-device mesh ==
    the single-device int8 fused path, for fused and fused_kernel (the
    fp32 scales shard with their factors) — and a Server on the mesh
    generates greedy tokens identical to the single-device fp32 store."""
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.launch.serve import Request, Server
        from repro.models import (build_model, compress_model_params,
                                  quantize_compressed_params)
        from repro.models.model import abstract_compressed_params
        from repro.launch.mesh import make_mesh
        from repro.sharding import make_rules, use_rules, shardings_from_axes

        cfg = reduced_config("mixtral-8x7b")
        cfg = dataclasses.replace(
            cfg,
            moe=dataclasses.replace(cfg.moe, ep_min_local_tokens=1,
                                    capacity_factor=8.0),
            resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                       keep_ratio=0.5))
        model = build_model(cfg)
        params, _ = model.init_split(jax.random.PRNGKey(0))
        cp, _ = compress_model_params(params, cfg)
        qp = quantize_compressed_params(cp)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
        ref, _ = jax.jit(
            lambda p, b: model.forward(p, b, apply_mode="fused"))(qp, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        abs_v, axes = abstract_compressed_params(cfg, store_dtype="int8")
        sh = shardings_from_axes(axes, rules, abs_v)
        for mode in ("fused", "fused_kernel"):
            def fwd(p, b, m=mode):
                with use_rules(rules):
                    return model.forward(p, b, apply_mode=m)[0]
            with mesh:
                p = jax.device_put(qp, sh)
                got = jax.jit(fwd)(p, batch)
            err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                        - ref.astype(jnp.float32))))
            assert err < 1e-3, (mode, err)

        # generation parity through the EP serving path: int8 store on
        # the mesh == fp32 store on a single device, token for token
        prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
        single = Server(model, cp, num_slots=2, max_seq=64,
                        apply_mode="fused")
        r1 = Request(prompt=prompt, max_new_tokens=5)
        single.serve([r1])
        sharded = Server(model, qp, num_slots=2, max_seq=64,
                         apply_mode="fused", rules=rules, param_axes=axes)
        r2 = Request(prompt=prompt, max_new_tokens=5)
        sharded.serve([r2])
        assert r1.output == r2.output, (r1.output, r2.output)
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# Persistence: compress-once / serve-many
# ---------------------------------------------------------------------------


def test_store_checkpoint_roundtrip(rng, tmp_path):
    """save/load of a compressed+quantized store is exact (int8 payloads
    and fp32 scales bit-identical) and serves the same logits."""
    from repro.checkpoint import (
        has_compressed_store,
        load_compressed_store,
        save_compressed_store,
    )

    cfg, model, cp, qp = _compressed_pair()
    path = str(tmp_path / "store")
    assert not has_compressed_store(path)
    meta = {"arch": "mixtral-8x7b", "store_dtype": "int8"}
    save_compressed_store(path, qp, meta=meta)
    assert has_compressed_store(path)
    loaded, got_meta = load_compressed_store(path)
    assert got_meta == meta

    flat_a, td_a = jax.tree_util.tree_flatten(qp)
    flat_b, td_b = jax.tree_util.tree_flatten(loaded)
    assert td_a == td_b
    for a, b in zip(flat_a, flat_b):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)),
                                   jnp.int32)}
    ref, _ = jax.jit(
        lambda p, b: model.forward(p, b, apply_mode="fused_kernel"))(qp, batch)
    got, _ = jax.jit(
        lambda p, b: model.forward(p, b, apply_mode="fused_kernel"))(
            loaded, batch)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_server_boot_from_store_never_compresses(rng, tmp_path,
                                                 monkeypatch):
    """Acceptance: a Server booted from a persisted store directory never
    calls compress_bank — compression is poisoned after the save and the
    loaded store still serves the original generations."""
    import repro.core.api as core_api
    import repro.core.compress as core_compress
    from repro.checkpoint import load_compressed_store, save_compressed_store

    cfg, model, cp, qp = _compressed_pair()
    prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
    srv = Server(model, qp, num_slots=2, max_seq=64, apply_mode="fused_kernel")
    r1 = Request(prompt=prompt, max_new_tokens=5)
    srv.serve([r1])

    path = str(tmp_path / "store")
    save_compressed_store(path, qp, meta={"store_dtype": "int8"})

    def boom(*a, **k):
        raise AssertionError("compress_bank must not run on a store boot")

    monkeypatch.setattr(core_compress, "compress_bank", boom)
    monkeypatch.setattr(core_api.ResMoECompressor, "compress_bank", boom)
    loaded, _ = load_compressed_store(path)
    srv2 = Server(model, loaded, num_slots=2, max_seq=64,
                  apply_mode="fused_kernel")
    r2 = Request(prompt=prompt, max_new_tokens=5)
    srv2.serve([r2])
    assert r2.output == r1.output


def test_quant_roofline_factor_bytes():
    """Mixtral-shape accounting: the int8 store moves >= 3.5x fewer factor
    HBM bytes than fp32 (the run itself asserts; re-check the rows)."""
    runtime = pytest.importorskip("benchmarks.runtime")
    rows = {r[0]: r[1] for r in runtime.quant_roofline_mixtral()}
    assert rows["T11/quant_roofline_mixtral/factor_bytes_x"] >= 3.5
