"""End-to-end system test: train a small MoE -> compress (ResMoE vs direct)
-> evaluate. The paper's central behavioural claim, scaled to CPU: at a
matched parameter budget, ResMoE-compressed models track the dense model's
quality far better than directly-compressed ones."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import make_pipeline
from repro.launch.train import run_training
from repro.models import build_model, compress_model_params


def _eval_nll(model, params, cfg, pipe, steps=4, apply_mode=None):
    tot = 0.0
    fwd = jax.jit(lambda p, b: model.forward(p, b, apply_mode=apply_mode))
    for i in range(1000, 1000 + steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        logits, _ = fwd(params, batch)
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][..., None], -1)[..., 0]
        tot += float((lse - gold).mean())
    return tot / steps


def test_train_compress_eval_system():
    out = run_training("mixtral-8x7b", steps=120, seq_len=64, global_batch=4,
                       lr=3e-3, log_every=40)
    losses = dict(out["losses"])
    assert losses[0] - out["losses"][-1][1] > 1.0, out["losses"]

    cfg = reduced_config("mixtral-8x7b")
    model = build_model(cfg)
    params = out["params"]
    pipe = make_pipeline(cfg, 64, 4)
    base_nll = _eval_nll(model, params, cfg, pipe)

    # ResMoE (UP) at 50%
    c1 = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="up", keep_ratio=0.5,
                                        apply_mode="restored"))
    cp1, rep1 = compress_model_params(params, c1)
    res_nll = _eval_nll(model, cp1, c1, pipe, apply_mode="restored")

    # direct UP at matched budget: zero the expert weights directly
    from repro.core.compress import design_matrices, split_design
    from repro.core.residual import prune_unstructured

    params_up = jax.tree_util.tree_map(
        lambda x: np.array(x, copy=True), params)
    f = params_up["segments"][0]["slots"][0]["ffn"]
    reps, n_exp = f["w1"].shape[:2]
    for r in range(reps):
        bank = {k: f[k][r] for k in ("w1", "w2", "w3")}
        design = design_matrices(bank)
        for k in range(n_exp):
            pruned = prune_unstructured(design[k], 0.5).to_dense()
            w = split_design(pruned, {m: bank[m][0] for m in bank})
            for m in bank:
                f[m][r][k] = w[m]
    up_nll = _eval_nll(model, params_up, cfg, pipe)

    # ResMoE must stay closer to the dense model than direct pruning
    assert res_nll - base_nll < up_nll - base_nll + 1e-6, (
        base_nll, res_nll, up_nll)
    # and must not blow up
    assert res_nll - base_nll < 1.0, (base_nll, res_nll)
