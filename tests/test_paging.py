"""PagePool invariants: allocation, conservation, block-table consistency.

The pool is pure host-side numpy (no jax import), so these tests are cheap
enough to fuzz: arbitrary alloc/free/preempt sequences run against a shadow
model and the three invariants from launch/paging.py's docstring are
asserted after every operation — no page is ever double-assigned, no page
leaks (free + owned == total, always), and block tables only ever point at
pages their slot owns. Hypothesis drives the sequences when installed (the
CI image has it); a seeded numpy fuzzer covers the bare-venv tier-1 run.
"""
import numpy as np
import pytest

from repro.launch.paging import PagePool

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 runs without dev extras (pyproject.toml)
    HAVE_HYPOTHESIS = False


# -- deterministic basics -----------------------------------------------------


def test_alloc_free_roundtrip():
    pool = PagePool(num_pages=4, page_size=8, num_slots=2, max_seq=32)
    assert pool.max_pages_per_slot == 4
    p0 = pool.alloc(0, 0)
    p1 = pool.alloc(0, 1)
    p2 = pool.alloc(1, 0)
    assert len({p0, p1, p2}) == 3
    assert pool.num_free == 1 and pool.pages_in_use == 3
    assert pool.has_page(0, 1) and not pool.has_page(1, 1)
    pool.check()
    freed = pool.free_slot(0)
    assert sorted(freed) == sorted([p0, p1])
    assert pool.num_free == 3
    assert not pool.has_page(0, 0)
    pool.check()


def test_pages_needed_rounds_up():
    pool = PagePool(num_pages=2, page_size=8, num_slots=1, max_seq=32)
    assert pool.pages_needed(1) == 1
    assert pool.pages_needed(8) == 1
    assert pool.pages_needed(9) == 2


def test_alloc_errors():
    pool = PagePool(num_pages=1, page_size=4, num_slots=2, max_seq=8)
    pool.alloc(0, 0)
    with pytest.raises(RuntimeError, match="already mapped"):
        pool.alloc(0, 0)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(1, 0)
    with pytest.raises(ValueError, match="slot"):
        pool.alloc(2, 0)
    with pytest.raises(ValueError, match="logical"):
        pool.alloc(1, 99)
    pool.check()


def test_free_slot_is_idempotent_and_isolated():
    pool = PagePool(num_pages=4, page_size=4, num_slots=3, max_seq=8)
    pool.alloc(0, 0)
    keep = pool.alloc(1, 0)
    assert pool.free_slot(2) == []  # never held anything
    pool.free_slot(0)
    assert pool.free_slot(0) == []
    assert pool.owner[keep] == 1  # slot 1 untouched
    pool.check()


# -- randomized alloc/free/preempt sequences ----------------------------------


def _run_random_ops(pool: PagePool, choose, n_ops: int):
    """Drive ``n_ops`` random ops, checking every invariant after each.

    ``choose(kind, options)`` picks from a list — hypothesis `data.draw`
    or a seeded numpy rng, so both fuzzers share one oracle loop.
    """
    handed_out = set()  # every page currently on loan, across all slots
    shadow = {s: set() for s in range(pool.num_slots)}  # slot -> owned
    for _ in range(n_ops):
        op = choose("op", ["alloc", "alloc", "free"])
        slot = choose("slot", list(range(pool.num_slots)))
        if op == "alloc":
            unmapped = [l for l in range(pool.max_pages_per_slot)
                        if not pool.has_page(slot, l)]
            if not unmapped:
                continue
            logical = choose("logical", unmapped)
            if pool.num_free == 0:
                with pytest.raises(RuntimeError, match="exhausted"):
                    pool.alloc(slot, logical)
            else:
                page = pool.alloc(slot, logical)
                # never double-assigned: the page was not on loan anywhere
                assert page not in handed_out
                handed_out.add(page)
                shadow[slot].add(page)
        else:  # free (finish or preempt — the pool cannot tell them apart)
            freed = pool.free_slot(slot)
            assert set(freed) == shadow[slot]
            handed_out -= shadow[slot]
            shadow[slot] = set()
        # conservation after EVERY op: nothing leaks, nothing double-counts
        assert pool.num_free + pool.pages_in_use == pool.num_pages
        assert pool.pages_in_use == len(handed_out)
        # block tables only map pages their slot owns
        for s in range(pool.num_slots):
            row = pool.block_tables[s]
            assert set(row[row >= 0].tolist()) == shadow[s]
        pool.check()


@pytest.mark.parametrize("seed", range(8))
def test_pool_invariants_seeded_fuzz(seed):
    rng = np.random.default_rng(seed)
    pool = PagePool(
        num_pages=int(rng.integers(1, 13)),
        page_size=int(rng.integers(1, 9)),
        num_slots=int(rng.integers(1, 6)),
        max_seq=int(rng.integers(1, 9)) * int(rng.integers(1, 7)),
    )
    _run_random_ops(
        pool, lambda kind, opts: opts[int(rng.integers(len(opts)))], 80)


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_pool_invariants_under_random_ops(data):
        num_pages = data.draw(st.integers(1, 12), label="num_pages")
        page_size = data.draw(st.integers(1, 8), label="page_size")
        num_slots = data.draw(st.integers(1, 5), label="num_slots")
        max_pages = data.draw(st.integers(1, 6), label="max_pages")
        pool = PagePool(num_pages, page_size, num_slots,
                        max_seq=max_pages * page_size)
        n_ops = data.draw(st.integers(0, 60), label="n_ops")
        _run_random_ops(
            pool,
            lambda kind, opts: data.draw(st.sampled_from(opts), label=kind),
            n_ops)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 10), st.integers(0, 200), st.integers(1, 4))
    def test_pool_churn_never_leaks(num_pages, rounds, num_slots):
        """Alternating full-allocation and full-release cycles return the
        pool to pristine state — LIFO reuse must not lose or duplicate
        pages."""
        pool = PagePool(num_pages, 4, num_slots, max_seq=4 * num_pages)
        rng = np.random.default_rng(rounds)
        for _ in range(rounds % 11):
            while pool.num_free:
                slot = int(rng.integers(num_slots))
                unmapped = [l for l in range(pool.max_pages_per_slot)
                            if not pool.has_page(slot, l)]
                if not unmapped:
                    break
                pool.alloc(slot, unmapped[0])
            for s in range(num_slots):
                pool.free_slot(s)
            assert pool.num_free == num_pages
            assert (pool.block_tables == -1).all()
            assert (pool.owner == -1).all()
            pool.check()
