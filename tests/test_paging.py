"""PagePool invariants: allocation, conservation, block-table consistency.

The pool is pure host-side numpy (no jax import), so these tests are cheap
enough to fuzz: arbitrary alloc/free/preempt sequences run against a shadow
model and the three invariants from launch/paging.py's docstring are
asserted after every operation — no page is ever double-assigned, no page
leaks (free + owned == total, always), and block tables only ever point at
pages their slot owns. Hypothesis drives the sequences when installed (the
CI image has it); a seeded numpy fuzzer covers the bare-venv tier-1 run.
"""
import numpy as np
import pytest

from repro.launch.paging import (PagePool, RecurrentSlots, ServingState,
                                 TokenPages)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 runs without dev extras (pyproject.toml)
    HAVE_HYPOTHESIS = False


# -- deterministic basics -----------------------------------------------------


def test_alloc_free_roundtrip():
    pool = PagePool(num_pages=4, page_size=8, num_slots=2, max_seq=32)
    assert pool.max_pages_per_slot == 4
    p0 = pool.alloc(0, 0)
    p1 = pool.alloc(0, 1)
    p2 = pool.alloc(1, 0)
    assert len({p0, p1, p2}) == 3
    assert pool.num_free == 1 and pool.pages_in_use == 3
    assert pool.has_page(0, 1) and not pool.has_page(1, 1)
    pool.check()
    freed = pool.free_slot(0)
    assert sorted(freed) == sorted([p0, p1])
    assert pool.num_free == 3
    assert not pool.has_page(0, 0)
    pool.check()


def test_pages_needed_rounds_up():
    pool = PagePool(num_pages=2, page_size=8, num_slots=1, max_seq=32)
    assert pool.pages_needed(1) == 1
    assert pool.pages_needed(8) == 1
    assert pool.pages_needed(9) == 2


def test_alloc_errors():
    pool = PagePool(num_pages=1, page_size=4, num_slots=2, max_seq=8)
    pool.alloc(0, 0)
    with pytest.raises(RuntimeError, match="already mapped"):
        pool.alloc(0, 0)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(1, 0)
    with pytest.raises(ValueError, match="slot"):
        pool.alloc(2, 0)
    with pytest.raises(ValueError, match="logical"):
        pool.alloc(1, 99)
    pool.check()


def test_free_page_roundtrip():
    pool = PagePool(num_pages=3, page_size=4, num_slots=2, max_seq=16)
    p0 = pool.alloc(0, 0)
    p1 = pool.alloc(0, 1)
    assert pool.free_page(0, 0) == p0
    assert not pool.has_page(0, 0) and pool.has_page(0, 1)
    assert pool.num_free == 2
    pool.check()
    # LIFO reuse: the reclaimed physical page comes straight back
    assert pool.alloc(1, 0) == p0
    assert pool.owner[p1] == 0  # untouched neighbour
    pool.check()


def test_free_page_errors():
    pool = PagePool(num_pages=2, page_size=4, num_slots=2, max_seq=8)
    with pytest.raises(RuntimeError, match="not mapped"):
        pool.free_page(0, 0)
    with pytest.raises(ValueError, match="slot"):
        pool.free_page(5, 0)
    with pytest.raises(ValueError, match="logical"):
        pool.free_page(0, 99)
    pool.check()


def test_free_slot_is_idempotent_and_isolated():
    pool = PagePool(num_pages=4, page_size=4, num_slots=3, max_seq=8)
    pool.alloc(0, 0)
    keep = pool.alloc(1, 0)
    assert pool.free_slot(2) == []  # never held anything
    pool.free_slot(0)
    assert pool.free_slot(0) == []
    assert pool.owner[keep] == 1  # slot 1 untouched
    pool.check()


def test_truncate_slot_frees_past_frontier():
    """Speculative rollback: truncate_slot releases exactly the mapped
    pages at logical >= keep_pages, leaves the kept prefix and other
    slots untouched, and is idempotent."""
    pool = PagePool(num_pages=6, page_size=4, num_slots=2, max_seq=24)
    for logical in range(4):
        pool.alloc(0, logical)
    keep = pool.alloc(1, 0)
    freed = pool.truncate_slot(0, 2)
    assert len(freed) == 2
    assert pool.has_page(0, 0) and pool.has_page(0, 1)
    assert not pool.has_page(0, 2) and not pool.has_page(0, 3)
    assert pool.owner[keep] == 1
    assert pool.truncate_slot(0, 2) == []  # idempotent
    # keep_pages past the table end is a harmless no-op, not an error
    assert pool.truncate_slot(0, pool.max_pages_per_slot + 3) == []
    with pytest.raises(ValueError, match="keep_pages"):
        pool.truncate_slot(0, -1)
    with pytest.raises(ValueError, match="slot"):
        pool.truncate_slot(9, 0)
    pool.check()


def test_truncate_slot_skips_window_holes():
    """A slot whose early pages were window-reclaimed has holes below the
    frontier; truncation must skip them instead of double-freeing."""
    pool = PagePool(num_pages=4, page_size=4, num_slots=1, max_seq=16)
    for logical in range(4):
        pool.alloc(0, logical)
    pool.free_page(0, 1)  # window hole
    freed = pool.truncate_slot(0, 3)
    assert len(freed) == 1 and not pool.has_page(0, 3)
    assert pool.has_page(0, 0) and pool.has_page(0, 2)
    pool.check()


def test_serving_state_truncate_recurrent_is_noop():
    """Pure-recurrent stacks hold no pages — ServingState.truncate must
    return [] (spec decoding refuses them before ever calling this, but
    the StatePage contract still has to hold)."""
    ss = ServingState([("rwkv", 8)] * 2, num_slots=2, max_seq=16,
                      page_size=4)
    assert ss.truncate(0, 3) == []
    ss.check()


# -- randomized alloc/free/preempt sequences ----------------------------------


def _run_random_ops(pool: PagePool, choose, n_ops: int):
    """Drive ``n_ops`` random ops, checking every invariant after each.

    ``choose(kind, options)`` picks from a list — hypothesis `data.draw`
    or a seeded numpy rng, so both fuzzers share one oracle loop.
    """
    handed_out = set()  # every page currently on loan, across all slots
    shadow = {s: set() for s in range(pool.num_slots)}  # slot -> owned
    for _ in range(n_ops):
        op = choose("op", ["alloc", "alloc", "free", "reclaim",
                           "speculate", "rollback"])
        slot = choose("slot", list(range(pool.num_slots)))
        if op == "speculate":
            # best-effort lookahead like ContinuousServer._ensure_pages:
            # map the lowest unmapped logical pages while the pool lasts,
            # never raising on exhaustion
            want = choose("lookahead", [1, 2, 3])
            for logical in range(pool.max_pages_per_slot):
                if want == 0 or pool.num_free == 0:
                    break
                if pool.has_page(slot, logical):
                    continue
                page = pool.alloc(slot, logical)
                assert page not in handed_out
                handed_out.add(page)
                shadow[slot].add(page)
                want -= 1
        elif op == "rollback":
            # speculative-decode rollback: truncate to a random frontier
            keep = choose("keep_pages",
                          list(range(pool.max_pages_per_slot + 1)))
            freed = pool.truncate_slot(slot, keep)
            assert set(freed) <= shadow[slot]
            assert len(set(freed)) == len(freed)
            for logical in range(keep, pool.max_pages_per_slot):
                assert not pool.has_page(slot, logical)
            handed_out -= set(freed)
            shadow[slot] -= set(freed)
        elif op == "alloc":
            unmapped = [l for l in range(pool.max_pages_per_slot)
                        if not pool.has_page(slot, l)]
            if not unmapped:
                continue
            logical = choose("logical", unmapped)
            if pool.num_free == 0:
                with pytest.raises(RuntimeError, match="exhausted"):
                    pool.alloc(slot, logical)
            else:
                page = pool.alloc(slot, logical)
                # never double-assigned: the page was not on loan anywhere
                assert page not in handed_out
                handed_out.add(page)
                shadow[slot].add(page)
        elif op == "reclaim":  # window expiry frees single mapped pages
            mapped = [l for l in range(pool.max_pages_per_slot)
                      if pool.has_page(slot, l)]
            if not mapped:
                with pytest.raises(RuntimeError, match="not mapped"):
                    pool.free_page(slot, 0)
                continue
            logical = choose("logical", mapped)
            page = pool.free_page(slot, logical)
            assert page in shadow[slot]
            handed_out.discard(page)
            shadow[slot].discard(page)
        else:  # free (finish or preempt — the pool cannot tell them apart)
            freed = pool.free_slot(slot)
            assert set(freed) == shadow[slot]
            handed_out -= shadow[slot]
            shadow[slot] = set()
        # conservation after EVERY op: nothing leaks, nothing double-counts
        assert pool.num_free + pool.pages_in_use == pool.num_pages
        assert pool.pages_in_use == len(handed_out)
        # block tables only map pages their slot owns
        for s in range(pool.num_slots):
            row = pool.block_tables[s]
            assert set(row[row >= 0].tolist()) == shadow[s]
        pool.check()


def _run_window_spec_ops(tp: TokenPages, choose, n_ops: int):
    """Spec rollback interleaved with window-expired reclamation — the
    serving loop's exact page life cycle on a sliding-window stack.

    Reclaim punches holes BELOW a slot's frontier; truncate frees pages
    PAST it. Neither may touch the other's range, double-free a page, or
    disturb the other slot, and the logical->mapped picture must match a
    shadow set after every op.
    """
    pool = tp.pool
    ps = pool.page_size
    max_seq = pool.max_pages_per_slot * ps
    pos = [0] * pool.num_slots          # committed frontier per slot
    mapped = [set() for _ in range(pool.num_slots)]  # logical pages

    def dead(logical, next_pos):
        return (logical + 1) * ps - 1 <= next_pos - tp.window

    for _ in range(n_ops):
        slot = choose("slot", list(range(pool.num_slots)))
        op = choose("op", ["advance", "advance", "reclaim", "lookahead",
                           "truncate", "release"])
        if op == "advance":
            # one decode step: map the frontier page if needed. The
            # frontier page can never be a window-dead hole (its last
            # position >= pos, and window >= 1), so alloc is legal.
            if pos[slot] >= max_seq:
                continue
            logical = pos[slot] // ps
            assert not dead(logical, pos[slot])
            if logical not in mapped[slot]:
                if pool.num_free == 0:
                    continue  # the real loop would preempt; skip here
                pool.alloc(slot, logical)
                mapped[slot].add(logical)
            pos[slot] += 1
        elif op == "lookahead":
            # spec-round best-effort mapping past the frontier
            k = choose("k", [1, 2, 3])
            for p in range(pos[slot], min(pos[slot] + k, max_seq)):
                logical = p // ps
                if logical in mapped[slot] or pool.num_free == 0:
                    continue
                pool.alloc(slot, logical)
                mapped[slot].add(logical)
        elif op == "reclaim":
            freed = tp.reclaim(slot, pos[slot])
            expect = {l for l in mapped[slot] if dead(l, pos[slot])}
            assert len(freed) == len(set(freed)) == len(expect)
            mapped[slot] -= expect
            # the frontier's own page never dies (its last position is
            # >= pos, and window >= 1); earlier pages may — with a
            # width-1 window even the last committed position is
            # invisible to the next query
            assert (pos[slot] // ps) not in expect
            assert tp.reclaim(slot, pos[slot]) == []  # idempotent
        elif op == "truncate":
            # end of a spec round: accept j tokens, roll the rest back
            j = choose("accepted", [0, 1, 2, 3])
            new_pos = min(pos[slot] + j, max_seq)
            freed = tp.truncate(slot, new_pos)
            keep = pool.pages_needed(new_pos)
            expect = {l for l in mapped[slot] if l >= keep}
            assert len(freed) == len(set(freed)) == len(expect)
            mapped[slot] -= expect
            pos[slot] = new_pos
            assert tp.truncate(slot, new_pos) == []  # idempotent
        else:  # release: finish or preemption
            freed = tp.release(slot)
            assert len(freed) == len(mapped[slot])
            mapped[slot] = set()
            pos[slot] = 0
        # shadow equivalence + conservation after EVERY op
        for s in range(pool.num_slots):
            for l in range(pool.max_pages_per_slot):
                assert pool.has_page(s, l) == (l in mapped[s]), (s, l)
        assert pool.num_free + pool.pages_in_use == pool.num_pages
        pool.check()
    for s in range(pool.num_slots):
        tp.release(s)
    assert pool.num_free == pool.num_pages


@pytest.mark.parametrize("seed", range(6))
def test_window_truncate_reclaim_seeded_fuzz(seed):
    rng = np.random.default_rng(seed)
    page_size = int(rng.integers(1, 7))
    max_pages = int(rng.integers(2, 7))
    max_seq = page_size * max_pages
    tp = TokenPages(num_pages=2 * max_pages + 2, page_size=page_size,
                    num_slots=2, max_seq=max_seq,
                    window=int(rng.integers(1, max_seq)))
    assert tp.reclaimable
    _run_window_spec_ops(
        tp, lambda kind, opts: opts[int(rng.integers(len(opts)))], 60)


@pytest.mark.parametrize("seed", range(8))
def test_pool_invariants_seeded_fuzz(seed):
    rng = np.random.default_rng(seed)
    pool = PagePool(
        num_pages=int(rng.integers(1, 13)),
        page_size=int(rng.integers(1, 9)),
        num_slots=int(rng.integers(1, 6)),
        max_seq=int(rng.integers(1, 9)) * int(rng.integers(1, 7)),
    )
    _run_random_ops(
        pool, lambda kind, opts: opts[int(rng.integers(len(opts)))], 80)


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_pool_invariants_under_random_ops(data):
        num_pages = data.draw(st.integers(1, 12), label="num_pages")
        page_size = data.draw(st.integers(1, 8), label="page_size")
        num_slots = data.draw(st.integers(1, 5), label="num_slots")
        max_pages = data.draw(st.integers(1, 6), label="max_pages")
        pool = PagePool(num_pages, page_size, num_slots,
                        max_seq=max_pages * page_size)
        n_ops = data.draw(st.integers(0, 60), label="n_ops")
        _run_random_ops(
            pool,
            lambda kind, opts: data.draw(st.sampled_from(opts), label=kind),
            n_ops)

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_window_truncate_reclaim_under_random_ops(data):
        """Hypothesis twin of the seeded window fuzz: truncate_slot
        (spec rollback) interleaved with window-expired reclamation."""
        page_size = data.draw(st.integers(1, 6), label="page_size")
        max_pages = data.draw(st.integers(2, 6), label="max_pages")
        max_seq = page_size * max_pages
        window = data.draw(st.integers(1, max_seq - 1), label="window") \
            if max_seq > 1 else 1
        tp = TokenPages(num_pages=2 * max_pages + 2, page_size=page_size,
                        num_slots=2, max_seq=max_seq, window=window)
        n_ops = data.draw(st.integers(0, 40), label="n_ops")
        _run_window_spec_ops(
            tp,
            lambda kind, opts: data.draw(st.sampled_from(opts), label=kind),
            n_ops)

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_speculative_write_rollback_invariants(data):
        """One full spec round against the pool, fuzzed over
        (page_size, slot_pos, spec_k, accept-length): allocate the
        committed prefix plus the round's lookahead pages (what
        _ensure_pages maps), accept a random prefix, truncate to the new
        frontier — exactly the pages wholly past it come back, the kept
        prefix and a neighbour slot are untouched, nothing leaks."""
        page_size = data.draw(st.integers(1, 8), label="page_size")
        max_pages = data.draw(st.integers(2, 6), label="max_pages")
        max_seq = page_size * max_pages
        tp = TokenPages(num_pages=2 * max_pages, page_size=page_size,
                        num_slots=2, max_seq=max_seq, window=None)
        pool = tp.pool
        # frontier with >= 1 position of headroom, like a live spec round
        slot_pos = data.draw(st.integers(1, max_seq - 1), label="slot_pos")
        spec_k = data.draw(st.integers(2, 6), label="spec_k")
        k = min(spec_k, max_seq - slot_pos)
        # pages covering committed prefix + the k speculative writes
        mapped = pool.pages_needed(slot_pos + k)
        for logical in range(mapped):
            pool.alloc(0, logical)
        neighbour = pool.alloc(1, 0)  # must survive slot 0's rollback
        # the round emits j in [1, k] tokens; frontier moves to pos + j
        j = data.draw(st.integers(1, k), label="accepted")
        new_pos = slot_pos + j
        freed = tp.truncate(0, new_pos)
        kept = pool.pages_needed(new_pos)
        assert len(freed) == mapped - kept
        for logical in range(kept):
            assert pool.has_page(0, logical)
        for logical in range(kept, pool.max_pages_per_slot):
            assert not pool.has_page(0, logical)
        assert pool.owner[neighbour] == 1
        assert pool.num_free + pool.pages_in_use == pool.num_pages
        assert tp.truncate(0, new_pos) == []  # idempotent
        pool.check()
        pool.free_slot(0)
        pool.free_slot(1)
        assert pool.num_free == pool.num_pages

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 10), st.integers(0, 200), st.integers(1, 4))
    def test_pool_churn_never_leaks(num_pages, rounds, num_slots):
        """Alternating full-allocation and full-release cycles return the
        pool to pristine state — LIFO reuse must not lose or duplicate
        pages."""
        pool = PagePool(num_pages, 4, num_slots, max_seq=4 * num_pages)
        rng = np.random.default_rng(rounds)
        for _ in range(rounds % 11):
            while pool.num_free:
                slot = int(rng.integers(num_slots))
                unmapped = [l for l in range(pool.max_pages_per_slot)
                            if not pool.has_page(slot, l)]
                if not unmapped:
                    break
                pool.alloc(slot, unmapped[0])
            for s in range(num_slots):
                pool.free_slot(s)
            assert pool.num_free == num_pages
            assert (pool.block_tables == -1).all()
            assert (pool.owner == -1).all()
            pool.check()


# -- StatePage layer: TokenPages / RecurrentSlots / ServingState --------------


def test_token_pages_reclaim_boundary_math():
    """A page is window-dead iff its LAST token is already invisible to the
    next query: (logical+1)*page_size - 1 <= next_pos - window."""
    tp = TokenPages(num_pages=8, page_size=4, num_slots=1, max_seq=32,
                    window=8)
    assert tp.reclaimable
    for logical in range(4):
        tp.pool.alloc(0, logical)
    # key k is visible to query q iff q - k < window, so k dies once every
    # future query q >= next_pos has q - k >= window, i.e. k <= next_pos - 8.
    # Page 0 covers keys [0,3]: its last key 3 dies exactly at next_pos=11.
    assert tp.reclaim(0, 10) == []
    dead = tp.reclaim(0, 11)
    assert len(dead) == 1 and not tp.pool.has_page(0, 0)
    tp.check()
    # idempotent: already-freed pages are not re-reported
    assert tp.reclaim(0, 11) == []
    # page 1 covers keys [4,7]: last key 7 dies at next_pos=15
    assert tp.reclaim(0, 14) == []
    assert len(tp.reclaim(0, 15)) == 1
    tp.check()


def test_token_pages_reclaim_off_for_global_window():
    tp = TokenPages(num_pages=4, page_size=4, num_slots=1, max_seq=16,
                    window=None)
    tp.pool.alloc(0, 0)
    assert not tp.reclaimable
    assert tp.reclaim(0, 16) == []  # global attention never expires keys
    wide = TokenPages(num_pages=4, page_size=4, num_slots=1, max_seq=16,
                      window=16)
    assert not wide.reclaimable  # window >= max_seq -> nothing ever dies


def test_serving_state_hybrid_demand():
    layout = [("rglru", 8), ("gqa", 8), ("rglru", 8), ("gqa", 64)]
    ss = ServingState(layout, num_slots=2, max_seq=32, page_size=4)
    assert ss.pages is not None and ss.slots is not None
    d = ss.demand(9)
    assert d == {"token_pages": 3, "state_slots": 1}
    # reclaim window is the max across attention layers (shared tables)
    assert ss.pages.window == 64
    assert not ss.pages.reclaimable  # 64 >= max_seq 32
    assert "token_pages" in ss.describe() and "recurrent_slots" in ss.describe()


def test_serving_state_pure_recurrent_has_no_pool():
    ss = ServingState([("rwkv", 8)] * 3, num_slots=2, max_seq=32, page_size=4)
    assert ss.pool is None and ss.slots is not None
    assert ss.demand(100) == {"token_pages": 0, "state_slots": 1}
    assert ss.admit_ok(100)  # state slot is pre-reserved with the slot
    assert ss.prepare(0, 5) is False  # nothing device-side to sync
    assert ss.release(0) == []
    ss.check()


def test_serving_state_rejects_unknown_mixer():
    with pytest.raises(ValueError, match="mixer"):
        ServingState([("mamba", 8)], num_slots=1, max_seq=8, page_size=4)


def test_serving_state_validate_demand_message():
    ss = ServingState([("gqa", 64)], num_slots=2, max_seq=16, page_size=4,
                      pool_pages=2)
    ss.validate_demand(4, 8)  # 2 pages: fits exactly
    with pytest.raises(ValueError, match="pool_pages"):
        ss.validate_demand(4, 12)  # needs 3 pages > pool of 2


def test_recurrent_slots_occupancy():
    rs = RecurrentSlots(num_slots=3, num_layers=2)
    assert rs.demand(999) == 1
    assert rs.prepare(1, 7) is False
    assert rs.occupied[1] and not rs.occupied[0]
    assert rs.release(1) == []
    assert not rs.occupied.any()
    rs.check()
