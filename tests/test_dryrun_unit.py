"""Dry-run machinery unit tests (no 512-device spawn)."""
import subprocess
import sys
import os

from repro.launch.dryrun import _op_histogram, collective_bytes_from_hlo


HLO = """
ENTRY %main {
  %p0 = bf16[2048,7168]{1,0} parameter(0)
  %ag = bf16[32768,7168]{1,0} all-gather(bf16[2048,7168]{1,0} %p0), replica_groups={}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%add
  %rs = bf16[1024]{0} reduce-scatter(bf16[16384]{0} %y), dimensions={0}
  %a2a = bf16[64,128]{1,0} all-to-all(bf16[64,128]{1,0} %z)
  %cp = u32[16]{0} collective-permute(u32[16]{0} %w), source_target_pairs={{0,1}}
  %fused = bf16[8]{0} fusion(bf16[8]{0} %q), kind=kLoop
}
"""


def test_collective_parser():
    res = collective_bytes_from_hlo(HLO)
    b = res["bytes"]
    assert b["all-gather"] == 32768 * 7168 * 2  # result bytes
    assert b["all-reduce"] == 1024 * 4
    assert b["reduce-scatter"] == 16384 * 2  # operand bytes
    assert b["all-to-all"] == 64 * 128 * 2
    assert b["collective-permute"] == 16 * 4
    assert res["counts"]["all-gather"] == 1
    assert res["total_bytes"] == sum(b.values())


def test_op_histogram():
    hist = _op_histogram(HLO)
    assert hist.get("all-gather") == 1
    assert hist.get("fusion") == 1


def test_default_microbatches():
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import _default_microbatches

    mb = _default_microbatches(get_config("llama3-405b"), SHAPES["train_4k"])
    assert mb >= 8 and SHAPES["train_4k"].global_batch % mb == 0
    mb_small = _default_microbatches(get_config("rwkv6-1.6b"), SHAPES["train_4k"])
    assert mb_small >= 1


def test_production_mesh_requires_devices():
    """On the 1-device test process the production mesh must refuse."""
    import pytest

    from repro.launch.mesh import make_production_mesh

    with pytest.raises(RuntimeError):
        make_production_mesh()


def test_dryrun_cli_single_cell_subprocess():
    """Full CLI path on the smallest cell, in its own 512-device process."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "rwkv6-1.6b",
         "--shape", "decode_32k", "--mesh", "both", "--out", "/tmp/dryrun_pytest"],
        env=env, capture_output=True, text=True, timeout=560,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "2/2 cells passed" in out.stdout
