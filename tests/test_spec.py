"""Barycenter-draft speculative decoding (launch/spec.py, DESIGN.md §12).

Layer-level parity for the drafter's ``center_only`` forward path, unit
tests for the acceptance oracle, the refusal rules, and a small
Server-level spec-vs-plain token-identity smoke. The full differential
matrix (ContinuousServer, preemption mid-speculation, page-boundary
rejections, both store dtypes) lives in tests/test_serve.py as a
``spec_k`` parametrization of the existing suites.

Parity coverage declared for scripts/check_parity_matrix.py:
# PARITY: center_only/fp32
# PARITY: center_only/int8
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import MoEConfig
from repro.core.quant import dequantize_store, quantize_store
from repro.launch.serve import Request, Server
from repro.launch.spec import accept_lengths, validate_spec_model
from repro.models import (
    build_model,
    compress_model_params,
    quantize_compressed_params,
)
from repro.models.moe import activation_fn, moe_layer, route


def _synthetic_store(rng, cfg, f=32, r=4):
    """A minimal SVD store shaped for ``cfg``'s router/activation."""
    d, e = cfg.d_model, cfg.moe.num_experts
    names = ("w1", "w3")
    center = {n: rng.normal(size=(d, f)).astype(np.float32) for n in names}
    center["w2"] = rng.normal(size=(f, d)).astype(np.float32)
    return {
        "router": rng.normal(size=(d, e)).astype(np.float32),
        "center": center,
        "u": rng.normal(size=(e, f, r)).astype(np.float32),
        "v": {n: rng.normal(size=(e, r, d)).astype(np.float32)
              for n in names + ("w2",)},
    }


def _center_reference(store, x, cfg):
    """Hand-rolled drafter math: y = (sum_k g_k) * FFN_center(x)."""
    b, s, d = x.shape
    x2d = jnp.asarray(np.asarray(x).reshape(-1, d))
    _, gates, _ = route({"router": jnp.asarray(store["router"])}, x2d,
                        cfg.moe)
    act = activation_fn(cfg.activation)
    c = store["center"]
    h = np.asarray(act(x2d @ c["w1"]))
    if "w3" in c:
        h = h * np.asarray(x2d @ c["w3"])
    y = h @ c["w2"]
    y = y * np.asarray(gates).sum(-1, keepdims=True)
    return y.reshape(b, s, d)


def test_center_only_matches_einsum_reference(rng):
    """apply_mode='center_only' collapses the routed mixture to one dense
    center FFN scaled by the token's gate mass — the per-expert u/v
    factors must never influence the output (corrupting them is a no-op).

    # PARITY: center_only/fp32
    """
    cfg = reduced_config("mixtral-8x7b")
    store = _synthetic_store(rng, cfg)
    x = jnp.asarray(rng.normal(size=(2, 5, cfg.d_model)), jnp.float32)
    out, aux = moe_layer(store, x, cfg, apply_mode="center_only")
    expected = _center_reference(store, x, cfg)
    np.testing.assert_allclose(np.asarray(out), expected, rtol=1e-4,
                               atol=1e-5)
    assert "load_balance_loss" in aux  # routing still runs (gate mass)
    poisoned = dict(store)
    poisoned["u"] = np.full_like(store["u"], 1e6)
    poisoned["v"] = {n: np.full_like(a, 1e6)
                     for n, a in store["v"].items()}
    out2, _ = moe_layer(poisoned, x, cfg, apply_mode="center_only")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_center_only_int8_store(rng):
    """center_only on an int8 store dequantizes the center in-graph and
    matches center_only on the explicitly dequantized store exactly —
    same dequant math, factors untouched.

    # PARITY: center_only/int8
    """
    cfg = reduced_config("mixtral-8x7b")
    store = _synthetic_store(rng, cfg)
    q = quantize_store({k: v for k, v in store.items() if k != "router"})
    q["router"] = store["router"]
    x = jnp.asarray(rng.normal(size=(2, 4, cfg.d_model)), jnp.float32)
    got, _ = moe_layer(q, x, cfg, apply_mode="center_only")
    deq = dequantize_store(q)
    ref_store = {"router": store["router"], "center": deq["center"],
                 "u": deq["u"], "v": deq["v"]}
    ref, _ = moe_layer(ref_store, x, cfg, apply_mode="center_only")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_center_only_rejects_dense_bank(rng):
    """A dense expert bank has no center to draft from — loud failure,
    checked BEFORE the EP gate so a mesh cannot mask it."""
    cfg = reduced_config("mixtral-8x7b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    f = params["segments"][0]["slots"][0]["ffn"]
    bank = {k: np.asarray(v[0]) for k, v in f.items()
            if k in ("router", "w1", "w2", "w3")}
    x = jnp.asarray(rng.normal(size=(1, 4, cfg.d_model)), jnp.float32)
    with pytest.raises(ValueError, match="center"):
        moe_layer(bank, x, cfg, apply_mode="center_only")


# ---------------------------------------------------------------------------
# Acceptance oracle
# ---------------------------------------------------------------------------


def test_accept_lengths_counts_leading_matches():
    drafts = np.array([[5, 6, 7],    # all accepted
                       [5, 9, 7],    # mismatch at index 1
                       [9, 6, 7]])   # instant mismatch
    oracle = np.array([[5, 6, 7, 1],
                       [5, 6, 7, 1],
                       [5, 6, 7, 1]])
    np.testing.assert_array_equal(accept_lengths(drafts, oracle), [3, 1, 0])


def test_accept_lengths_k1_degenerates():
    """A k=1 round has no drafts: a == 0 everywhere, i.e. plain decode
    (exactly one oracle token emitted per slot)."""
    drafts = np.zeros((4, 0), np.int64)
    oracle = np.array([[3], [1], [4], [1]])
    np.testing.assert_array_equal(accept_lengths(drafts, oracle),
                                  [0, 0, 0, 0])


def test_accept_lengths_no_resurrection_after_mismatch():
    """A match AFTER the first mismatch must not count — acceptance is a
    prefix property (the later 'match' was conditioned on a rejected
    token)."""
    drafts = np.array([[7, 9, 7]])
    oracle = np.array([[7, 8, 7, 2]])
    np.testing.assert_array_equal(accept_lengths(drafts, oracle), [1])


# ---------------------------------------------------------------------------
# Refusal rules
# ---------------------------------------------------------------------------


def _compressed_mixtral(seed=0):
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                        keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(seed))
    cp, _ = compress_model_params(params, cfg)
    return cfg, model, cp


def test_spec_refuses_non_greedy():
    cfg, model, cp = _compressed_mixtral()
    with pytest.raises(ValueError, match="greedy"):
        validate_spec_model(model, cp, greedy=False)
    with pytest.raises(ValueError, match="greedy"):
        Server(model, cp, num_slots=2, max_seq=32, apply_mode="fused",
               greedy=False, spec_k=2)


def test_spec_refuses_uncompressed_params():
    cfg = reduced_config("mixtral-8x7b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="compress"):
        Server(model, params, num_slots=2, max_seq=32, spec_k=2)


def test_spec_refuses_recurrent_mixers():
    """Recurrent state advances per drafted token with no per-position
    axis to roll back — spec must refuse the hybrid compressed-MoE
    recurrentgemma stack even though it HAS a center to draft with."""
    cfg = reduced_config("recurrentgemma-9b")
    cfg = dataclasses.replace(
        cfg,
        moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=128,
                      capacity_factor=8.0),
        resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                   keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    with pytest.raises(ValueError, match="recurrent"):
        validate_spec_model(model, cp, greedy=True)


# ---------------------------------------------------------------------------
# Server-level token identity (the full matrix rides test_serve.py)
# ---------------------------------------------------------------------------


def test_server_spec_decode_token_identical(rng):
    """spec_k=4 on the sync Server emits exactly the spec_k=0 tokens, and
    the upcycled reduced config (center ~= experts) accepts drafts — the
    latency win is real, not just not-wrong."""
    cfg, model, cp = _compressed_mixtral()
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(3)]
    plain = [Request(prompt=p, max_new_tokens=8) for p in prompts]
    Server(model, cp, num_slots=2, max_seq=32,
           apply_mode="fused_kernel").serve(plain)
    spec = [Request(prompt=p, max_new_tokens=8) for p in prompts]
    srv = Server(model, cp, num_slots=2, max_seq=32,
                 apply_mode="fused_kernel", spec_k=4)
    srv.serve(spec)
    for a, b in zip(plain, spec):
        assert a.output == b.output, (a.output, b.output)
    assert srv.spec_stats["rounds"] > 0
    assert srv.spec_stats["accepted"] > 0, srv.spec_stats


def test_server_spec_k1_is_plain_decode(rng):
    """spec_k in {0, 1} never builds a drafter — a 1-token round IS a
    decode step, so the spec machinery must stay cold."""
    cfg, model, cp = _compressed_mixtral()
    srv = Server(model, cp, num_slots=2, max_seq=32, apply_mode="fused",
                 spec_k=1)
    assert srv.drafter is None
    req = Request(prompt=rng.integers(0, cfg.vocab_size, size=(5,))
                  .astype(np.int32), max_new_tokens=4)
    srv.serve([req])
    assert len(req.output) == 4
    assert srv.spec_stats == {"rounds": 0, "drafted": 0, "accepted": 0}


def test_server_spec_int8_store_token_identical(rng):
    """The drafter dequantizes the int8 center in-graph: spec on the int8
    store matches plain decode on the SAME int8 store token-for-token."""
    cfg, model, cp = _compressed_mixtral()
    qp = quantize_compressed_params(cp)
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(2)]
    plain = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    Server(model, qp, num_slots=2, max_seq=32,
           apply_mode="fused_token").serve(plain)
    spec = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    Server(model, qp, num_slots=2, max_seq=32, apply_mode="fused_token",
           spec_k=2).serve(spec)
    for a, b in zip(plain, spec):
        assert a.output == b.output, (a.output, b.output)
