"""Optimizer unit tests: AdamW, Adafactor, clipping, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    cosine_warmup_schedule,
    make_optimizer,
)
from repro.optim.api import clip_by_global_norm, global_norm


def _quadratic_losses(update_fn, init_fn, steps=60, lr=0.1):
    """Minimize ||x - t||^2 with the optimizer; return loss trace."""
    t = jnp.asarray(np.random.default_rng(0).normal(size=(16, 130)), jnp.float32)
    params = {"x": jnp.zeros_like(t)}
    state = init_fn(params)

    def loss(p):
        return jnp.sum((p["x"] - t) ** 2)

    traces = []
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state = update_fn(g, state, params, lr)
        traces.append(float(loss(params)))
    return traces


def test_adamw_converges():
    tr = _quadratic_losses(
        lambda g, s, p, lr: adamw_update(g, s, p, lr, weight_decay=0.0),
        adamw_init,
    )
    assert tr[-1] < 0.05 * tr[0]


def test_adafactor_converges():
    # adafactor clips the update RMS, so lr ~ the per-step movement; 0.1
    # converges smoothly where 0.5 oscillates (verified by sweep).
    tr = _quadratic_losses(
        lambda g, s, p, lr: adafactor_update(g, s, p, lr, weight_decay=0.0),
        adafactor_init, steps=120, lr=0.1,
    )
    assert tr[-1] < 0.01 * tr[0]


def test_adafactor_state_is_factored():
    p = {"w": jnp.zeros((256, 512)), "b": jnp.zeros((7,))}
    s = adafactor_init(p)
    assert set(s["stats"]["w"]) == {"vr", "vc"}
    assert s["stats"]["w"]["vr"].shape == (256,)
    assert s["stats"]["w"]["vc"].shape == (512,)
    assert set(s["stats"]["b"]) == {"v"}  # small tensors unfactored
    # O(m+n) vs O(mn) memory
    fac = s["stats"]["w"]["vr"].size + s["stats"]["w"]["vc"].size
    assert fac < 0.01 * p["w"].size


def test_weight_decay_shrinks_params():
    p = {"x": jnp.ones((8, 8))}
    s = adamw_init(p)
    zero_g = {"x": jnp.zeros((8, 8))}
    p2, _ = adamw_update(zero_g, s, p, lr=0.1, weight_decay=0.5)
    assert float(p2["x"].mean()) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 10.0, rtol=1e-5)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-4)
    # below the bound: untouched
    same, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_cosine_warmup_schedule():
    lr = cosine_warmup_schedule(1e-3, warmup_steps=10, total_steps=100,
                                final_frac=0.1)
    assert float(lr(0)) < float(lr(5)) < float(lr(9))
    np.testing.assert_allclose(float(lr(10)), 1e-3, rtol=1e-2)
    assert float(lr(99)) < 1.2e-4 + 1e-5
    # monotone decay after warmup
    vals = [float(lr(s)) for s in range(10, 100, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_optimizer_facade_counts_steps():
    opt = make_optimizer("adamw", cosine_warmup_schedule(1e-3, 2, 10))
    p = {"x": jnp.ones((4,))}
    s = opt.init(p)
    g = {"x": jnp.ones((4,))}
    p, s, m = opt.update(g, s, p)
    assert int(s["count"]) == 1
    assert "grad_norm" in m and "lr" in m
