import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device. Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_clustered_design(rng, n_experts=6, p_i=32, d=24, noise=0.25, distinct=0.5):
    """Synthetic expert bank design tensor with ResMoE-favourable structure:
    common pattern + per-expert distinct component + noise, rows shuffled."""
    base = rng.normal(size=(p_i, d))
    mats = []
    for _ in range(n_experts):
        own = distinct * rng.normal(size=(p_i, d))
        perm = rng.permutation(p_i)
        mats.append((base + own + noise * rng.normal(size=(p_i, d)))[perm])
    return np.stack(mats).astype(np.float64)


def make_bank(rng, n=4, d=16, f=24, glu=True):
    bank = {
        "w1": rng.normal(size=(n, d, f)).astype(np.float32),
        "w2": rng.normal(size=(n, f, d)).astype(np.float32),
    }
    if glu:
        bank["w3"] = rng.normal(size=(n, d, f)).astype(np.float32)
    return bank
