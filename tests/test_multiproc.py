"""Multi-host routed serving, CPU-simulated: two worker processes == oracle.

The in-process router differentials (tests/test_router.py) cannot reach
the bring-up path — ``jax.distributed.initialize``, process-indexed
assignment, per-host device simulation — because a test process can join
a coordination service exactly once. So this suite (the ``multiproc``
CI tier) launches two real ``python -m repro.launch.router`` worker
subprocesses under one coordinator, each simulating a 2-device host,
lets each serve its deterministic share of the same seeded trace, and
diffs the routed union token-for-token against the sync ``Server``
oracle computed in-process — forced preemption and the disaggregated
pair included.
"""
import json
import os
import socket
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.serve import Request, Server
from repro.models import build_model

pytestmark = pytest.mark.multiproc

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_N_REQS = 6
_MAX_NEW = 5


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _run_hosts(tmp_path, extra_args=()):
    """Launch 2 worker processes under one coordinator; return their
    parsed JSON outputs (host order)."""
    port = _free_port()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # let init_distributed own the device-count flag deterministically
    env.pop("XLA_FLAGS", None)
    outs = [str(tmp_path / f"host{i}.json") for i in range(2)]
    cmd = [sys.executable, "-m", "repro.launch.router",
           "--coordinator", f"127.0.0.1:{port}", "--num-hosts", "2",
           "--simulate-devices", "2", "--requests", str(_N_REQS),
           "--max-new", str(_MAX_NEW), *extra_args]
    procs = [subprocess.Popen(cmd + ["--host", str(i), "--out", outs[i]],
                              cwd=_ROOT, env=env, stdout=subprocess.PIPE,
                              stderr=subprocess.STDOUT, text=True)
             for i in range(2)]
    logs = []
    for p in procs:
        try:
            out, _ = p.communicate(timeout=420)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            pytest.fail("multiproc worker timed out")
        logs.append(out)
        assert p.returncode == 0, f"worker failed:\n{out}"
    return [json.load(open(o)) for o in outs], logs


def _oracle_outputs():
    """The same seeded trace repro.launch.router::main builds, served
    through the sync oracle in this process."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=(8,))
                    .astype(np.int32), max_new_tokens=_MAX_NEW)
            for _ in range(_N_REQS)]
    Server(model, params, num_slots=3, max_seq=48).serve(reqs)
    return {str(i): r.output for i, r in enumerate(reqs)}


def _assert_union_matches(results, oracle):
    # both hosts derived the identical assignment with no coordination
    assert results[0]["assignment"] == results[1]["assignment"]
    # the shares are disjoint and cover the trace
    mine = [set(r["outputs"]) for r in results]
    assert not (mine[0] & mine[1])
    assert mine[0] | mine[1] == set(oracle)
    union = {**results[0]["outputs"], **results[1]["outputs"]}
    for i, want in oracle.items():
        assert union[i] == want, (i, union[i], want)


def test_two_hosts_routed_union_matches_oracle(tmp_path):
    results, _ = _run_hosts(tmp_path)
    for r in results:
        # jax.distributed really federated the simulated hosts: each
        # process sees its 2 local devices AND the other host's 2
        assert r["hosts"] == 2
        assert r["local_devices"] == 2
        assert r["global_devices"] == 4
    _assert_union_matches(results, _oracle_outputs())


def test_two_hosts_disaggregated_with_preemption(tmp_path):
    """The hard mode: each host serves through the prefill/decode
    disaggregated pair with a forced mid-request eviction — resumes
    re-enter through the prefill worker on whichever host owns them,
    and the union must still match the oracle bit-for-bit."""
    results, _ = _run_hosts(
        tmp_path, extra_args=["--disaggregate", "--preempt-step", "2"])
    assert sum(r["preemptions"] for r in results) >= 1
    _assert_union_matches(results, _oracle_outputs())
