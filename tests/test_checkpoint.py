"""Checkpointer: atomic commit, async, restore, gc, resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, reshard


def _tree(rng):
    return {
        "a": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 10, (3,)), jnp.int32),
                   "c": [jnp.ones((2, 2), jnp.bfloat16)]},
        "count": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_bitwise(tmp_path, rng):
    ckpt = Checkpointer(str(tmp_path))
    tree = _tree(rng)
    ckpt.save(5, tree)
    got, extra = ckpt.restore(5, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path, rng):
    ckpt = Checkpointer(str(tmp_path))
    tree = _tree(rng)
    ckpt.save_async(3, tree, extra={"loss": 1.5})
    ckpt.wait()
    assert latest_step(str(tmp_path)) == 3
    got, extra = ckpt.restore(3, tree)
    assert extra == {"loss": 1.5}


def test_latest_ignores_tmp(tmp_path, rng):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, _tree(rng))
    os.makedirs(tmp_path / "step_000099.tmp")  # simulated crash mid-write
    assert latest_step(str(tmp_path)) == 1


def test_gc_keeps_latest(tmp_path, rng):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    tree = _tree(rng)
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_000003", "step_000004"]


def test_shape_mismatch_rejected(tmp_path, rng):
    ckpt = Checkpointer(str(tmp_path))
    tree = _tree(rng)
    ckpt.save(1, tree)
    bad = dict(tree)
    bad["a"] = jnp.zeros((5, 8), jnp.float32)
    with pytest.raises(ValueError):
        ckpt.restore(1, bad)


def test_crash_resume_training(tmp_path):
    """Injected failures mid-run: supervisor restores and completes, and the
    final params match a failure-free run (deterministic data replay)."""
    from repro.launch.train import run_training

    clean = run_training("granite-8b", steps=12, seq_len=16, global_batch=2,
                         ckpt_dir=str(tmp_path / "a"), checkpoint_every=4,
                         log_every=4)
    faulty = run_training("granite-8b", steps=12, seq_len=16, global_batch=2,
                          ckpt_dir=str(tmp_path / "b"), checkpoint_every=4,
                          log_every=4, fail_at=(6, 9))
    assert faulty["restarts"] == 2
    for a, b in zip(jax.tree_util.tree_leaves(clean["params"]),
                    jax.tree_util.tree_leaves(faulty["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
