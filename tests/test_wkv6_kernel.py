"""Chunked RWKV6 Pallas kernel: allclose vs the scan oracle + model core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv6 import wkv6_chunk, wkv6_ref


@pytest.mark.parametrize("bh,t,hd", [(4, 16, 8), (2, 33, 64), (8, 7, 16),
                                     (1, 128, 64)])
def test_wkv6_kernel_allclose(bh, t, hd, rng):
    r, k, v = [jnp.asarray(rng.normal(size=(bh, t, hd)), jnp.float32)
               for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.8, 0.999, (bh, t, hd)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(bh, hd)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(bh, hd, hd)), jnp.float32)
    y, sf = wkv6_chunk(r, k, v, w, u, s0, interpret=True)
    yr, sr = wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr), rtol=1e-5,
                               atol=1e-5)


def test_wkv6_matches_model_core(rng):
    """Kernel oracle == the transformer's _wkv6_scan on reshaped inputs."""
    from repro.models.recurrent import _wkv6_scan

    b, s, h, hd = 2, 12, 3, 8
    r, k, v = [jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
               for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.8, 0.999, (b, s, h, hd)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, hd)), jnp.float32)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    y_model, s_model = _wkv6_scan(r, k, v, w, u, s0)

    def flat(x):  # [B,S,H,hd] -> [B*H, S, hd]
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)

    u_flat = jnp.tile(u, (b, 1))
    y_k, s_k = wkv6_chunk(flat(r), flat(k), flat(v), flat(w), u_flat,
                          s0.reshape(b * h, hd, hd), interpret=True)
    y_k = y_k.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_model),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k.reshape(b, h, hd, hd)),
                               np.asarray(s_model), rtol=1e-5, atol=1e-5)
