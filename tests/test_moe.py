"""MoE layer invariants: routing, dispatch/combine, ResMoE forward paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import MoEConfig
from repro.models import build_model, compress_model_params
from repro.models.moe import (
    combine_tokens,
    dispatch_tokens,
    expert_capacity,
    make_dispatch,
    moe_layer,
    route,
)


def _moe_cfg(**kw):
    cfg = reduced_config("mixtral-8x7b")
    if kw:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **kw))
    return cfg


def test_dispatch_combine_is_weighted_sum(rng):
    """With ample capacity, dispatch+identity-experts+combine must equal
    sum_k gate_k * x for every token."""
    t, d, e, k = 32, 8, 4, 2
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    expert_ids = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    # ensure distinct experts per token for clean accounting
    expert_ids = expert_ids.at[:, 1].set((expert_ids[:, 0] + 1) % e)
    gates = jnp.asarray(rng.random((t, k)), jnp.float32)
    cap = t * k  # no drops
    token_idx, dest, keep, sort_idx = make_dispatch(expert_ids, e, cap)
    assert bool(keep.all())
    xg = dispatch_tokens(x, token_idx, dest, keep, e, cap)
    out = combine_tokens(xg, gates.reshape(-1), token_idx, dest, keep, t, sort_idx)
    expected = (gates.sum(-1, keepdims=True)) * x
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-5)


def test_capacity_drops_exactly(rng):
    t, e, k = 64, 4, 1
    expert_ids = jnp.zeros((t, k), jnp.int32)  # all tokens to expert 0
    cap = 16
    token_idx, dest, keep, _ = make_dispatch(expert_ids, e, cap)
    assert int(keep.sum()) == cap


def test_route_topk_properties(rng):
    cfg = _moe_cfg()
    m = cfg.moe
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    # find the moe params of layer 0
    f = params["segments"][0]["slots"][0]["ffn"]
    bank = {k: v[0] for k, v in f.items() if hasattr(v, "shape")}
    bank["router"] = f["router"][0]
    x = jnp.asarray(rng.normal(size=(16, cfg.d_model)), jnp.float32)
    ids, gates, aux = route({"router": bank["router"]}, x, m)
    assert ids.shape == (16, m.top_k)
    assert gates.shape == (16, m.top_k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    # distinct experts per token
    assert int((ids[:, 0] == ids[:, 1]).sum()) == 0
    assert float(aux["load_balance_loss"]) >= 0.99  # >= 1 at balance optimum


def test_sigmoid_router(rng):
    cfg = _moe_cfg(router_type="sigmoid")
    x = jnp.asarray(rng.normal(size=(8, cfg.d_model)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(cfg.d_model, cfg.moe.num_experts)),
                         jnp.float32)
    ids, gates, _ = route(
        {"router": router, "router_bias": jnp.zeros(cfg.moe.num_experts)},
        x, cfg.moe)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)


def test_moe_layer_expert_permutation_invariance(rng):
    """Permuting an expert's bottleneck rows (w1/w3 cols, w2 rows) must not
    change the layer output — the symmetry ResMoE builds on."""
    cfg = _moe_cfg()
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    f = params["segments"][0]["slots"][0]["ffn"]
    bank = {k: np.asarray(v[0]) for k, v in f.items()
            if k in ("router", "w1", "w2", "w3")}
    x = jnp.asarray(rng.normal(size=(2, 6, cfg.d_model)), jnp.float32)
    out0, _ = moe_layer(bank, x, cfg)
    perm = rng.permutation(bank["w1"].shape[-1])
    bank2 = dict(bank)
    bank2["w1"] = bank["w1"][:, :, perm]
    bank2["w3"] = bank["w3"][:, :, perm]
    bank2["w2"] = bank["w2"][:, perm, :]
    out1, _ = moe_layer(bank2, x, cfg)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                               rtol=1e-4, atol=1e-5)


def test_resmoe_paths_agree(rng):
    """restored / fused / fused_shared must agree exactly (same math).

    # PARITY: restored/fp32
    # PARITY: fused/fp32
    # PARITY: fused_shared/fp32
    """
    cfg = _moe_cfg()
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd", keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(1))
    cp, _ = compress_model_params(params, cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)),
                                   jnp.int32)}
    outs = {}
    for mode in ("restored", "fused", "fused_shared"):
        logits, _ = jax.jit(
            lambda p, b, m=mode: model.forward(p, b, apply_mode=m))(cp, batch)
        outs[mode] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["restored"], outs["fused"], rtol=5e-3,
                               atol=5e-3)
    np.testing.assert_allclose(outs["fused"], outs["fused_shared"], rtol=5e-3,
                               atol=5e-3)


def test_route_softmax_unnormalized_topk_gate_shape(rng):
    """router_type=softmax + normalize_gates=False + top_k>1: gates must be
    the full-softmax probabilities of the selected experts, shape [T, k].
    (A .max(-1) regression collapsed them to [T, 1], so combine_tokens read
    gates_flat out of bounds — silently clamped by jnp gather.)"""
    cfg = _moe_cfg(normalize_gates=False, top_k=2)
    m = cfg.moe
    t = 16
    router = jnp.asarray(rng.normal(size=(cfg.d_model, m.num_experts)),
                         jnp.float32)
    x = jnp.asarray(rng.normal(size=(t, cfg.d_model)), jnp.float32)
    ids, gates, _ = route({"router": router}, x, m)
    assert gates.shape == (t, m.top_k)
    logits = np.asarray(x, np.float32) @ np.asarray(router)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    expected = np.take_along_axis(probs, np.asarray(ids), axis=-1)
    np.testing.assert_allclose(np.asarray(gates), expected, rtol=1e-5,
                               atol=1e-6)


def test_combine_correct_with_unnormalized_gates(rng):
    """End-to-end moe_layer under normalize_gates=False must equal a manual
    per-token sum of gate_k * expert_k(x) — the combine path the [T, 1] gate
    bug corrupted for k=2."""
    cfg = _moe_cfg(normalize_gates=False, top_k=2, capacity_factor=8.0)
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(3))
    f = params["segments"][0]["slots"][0]["ffn"]
    bank = {k: np.asarray(v[0]) for k, v in f.items()
            if k in ("router", "w1", "w2", "w3")}
    x = jnp.asarray(rng.normal(size=(1, 5, cfg.d_model)), jnp.float32)
    out, _ = moe_layer(bank, x, cfg)

    x2d = np.asarray(x, np.float32).reshape(-1, cfg.d_model)
    ids, gates, _ = route({"router": jnp.asarray(bank["router"])},
                          jnp.asarray(x2d), cfg.moe)
    ids, gates = np.asarray(ids), np.asarray(gates, np.float32)

    def expert(i, xt):
        import jax

        h = jax.nn.silu(xt @ bank["w1"][i]) * (xt @ bank["w3"][i])
        return np.asarray(h @ bank["w2"][i])

    expected = np.zeros_like(x2d)
    for t in range(x2d.shape[0]):
        for j in range(cfg.moe.top_k):
            expected[t] += gates[t, j] * expert(ids[t, j], x2d[t])
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model),
                               expected, rtol=2e-4, atol=2e-4)


def test_resmoe_fused_kernel_matches_fused(rng):
    """apply_mode='fused_kernel' (grouped Pallas kernel) must match the
    einsum fused path through the full model, GLU included.

    # PARITY: fused_kernel/fp32
    """
    cfg = _moe_cfg()
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd", keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(1))
    cp, _ = compress_model_params(params, cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)),
                                   jnp.int32)}
    outs = {}
    for mode in ("fused", "fused_kernel"):
        logits, _ = jax.jit(
            lambda p, b, m=mode: model.forward(p, b, apply_mode=m))(cp, batch)
        outs[mode] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["fused"], outs["fused_kernel"],
                               rtol=1e-4, atol=1e-4)


def test_resmoe_up_keep1_lossless(rng):
    cfg = _moe_cfg()
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="up", keep_ratio=1.0,
                                        apply_mode="restored"))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(2))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)),
                                   jnp.int32)}
    base, _ = jax.jit(model.forward)(params, batch)
    cp, report = compress_model_params(params, cfg)
    comp, _ = jax.jit(lambda p, b: model.forward(p, b, apply_mode="restored"))(
        cp, batch)
    np.testing.assert_allclose(np.asarray(comp), np.asarray(base),
                               rtol=1e-4, atol=1e-4)
    assert report.mean_approx_error < 1e-8


def test_expert_capacity_rounding():
    m = MoEConfig(num_experts=8, top_k=2, expert_d_ff=64, capacity_factor=1.25)
    c = expert_capacity(1024, m)
    assert c % 8 == 0 and c >= 1.25 * 1024 * 2 / 8
