"""OT solver unit tests: exact assignment, auction oracle, Sinkhorn."""
import numpy as np
import pytest

from repro.core.ot import (
    auction_assignment,
    exact_assignment,
    ot_permutation,
    pairwise_sq_dists,
    round_plan_to_permutation,
    sinkhorn,
)


def _cost(rng, n):
    x = rng.normal(size=(n, 8))
    y = rng.normal(size=(n, 8))
    return pairwise_sq_dists(x, y), x, y


def test_exact_assignment_beats_random(rng):
    c, _, _ = _cost(rng, 16)
    perm = exact_assignment(c)
    opt = c[perm, np.arange(16)].sum()
    for _ in range(50):
        p = rng.permutation(16)
        assert opt <= c[p, np.arange(16)].sum() + 1e-9


def test_exact_is_permutation(rng):
    c, _, _ = _cost(rng, 33)
    perm = exact_assignment(c)
    assert sorted(perm) == list(range(33))


def test_auction_matches_scipy(rng):
    for n in (4, 9, 17):
        c, _, _ = _cost(rng, n)
        p_scipy = exact_assignment(c)
        p_auction = auction_assignment(c)
        v1 = c[p_scipy, np.arange(n)].sum()
        v2 = c[p_auction, np.arange(n)].sum()
        assert v2 <= v1 * (1 + 1e-6) + 1e-6  # auction is eps-optimal


def test_permutation_recovery(rng):
    """Aligning a shuffled copy of a matrix must recover the shuffle."""
    x = rng.normal(size=(24, 12))
    perm = rng.permutation(24)
    y = x[perm]
    got = ot_permutation(y, x)  # y[got] should equal x
    np.testing.assert_array_equal(y[got], x)


def test_sinkhorn_marginals(rng):
    c, _, _ = _cost(rng, 12)
    plan = np.asarray(sinkhorn(c.astype(np.float32), 0.05, 300))
    np.testing.assert_allclose(plan.sum(1), np.full(12, 1 / 12), atol=1e-3)
    np.testing.assert_allclose(plan.sum(0), np.full(12, 1 / 12), atol=1e-3)


def test_sinkhorn_rounding_is_permutation(rng):
    c, _, _ = _cost(rng, 10)
    plan = np.asarray(sinkhorn(c.astype(np.float32), 0.02, 500))
    perm = round_plan_to_permutation(plan)
    assert sorted(perm) == list(range(10))


def test_sinkhorn_near_exact_on_separated(rng):
    """With well-separated points, Sinkhorn + rounding = exact solution."""
    x = rng.normal(size=(8, 4)) * 10
    perm = rng.permutation(8)
    y = x[perm]
    got = ot_permutation(y, x, solver="sinkhorn", reg=0.01, iters=500)
    np.testing.assert_array_equal(y[got], x)
