"""Overlapped serving engine: ``OverlappedServer`` == the sync oracle.

launch/engine.py wraps the ContinuousServer scheduler in background
admission + detokenize threads (DESIGN.md §13). Nothing about the
threading may change greedy outputs, so the heavy differentials here
(``engine`` CI tier) pin the engine token-for-token against the
slot-synchronous ``Server`` across randomized schedules — dense, MoE,
recurrent, hybrid — with forced preemption-restore and speculative
rounds included. The unmarked tests run in tier-1: the per-row expert
capacity argument behind batched admission prefill, the warmup
no-recompile pin (jax executable-cache counters), the stats schema
both paged servers promise docs/SERVING.md, and the engine's
constructor refusals.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import MoEConfig
from repro.launch.engine import OverlappedServer
from repro.launch.serve import ContinuousServer, Request, Server
from repro.models import build_model, compress_model_params
from repro.models.moe import moe_layer
from repro.sharding import split_logical


def _random_schedule(seed, vocab, n_lo=2, n_hi=5, max_new_hi=7):
    """Same shape as test_serve's schedules: a few prompts of length
    {4, 6, 8}, random budgets, a permuted submission order and sorted
    Poisson arrival steps (open-loop trace)."""
    r = np.random.default_rng(seed)
    n = int(r.integers(n_lo, n_hi + 1))
    prompts = [r.integers(0, vocab, size=(int(r.choice([4, 6, 8])),))
               .astype(np.int32) for _ in range(n)]
    max_new = [int(r.integers(1, max_new_hi)) for _ in range(n)]
    order = r.permutation(n)
    arrivals = np.sort(r.poisson(1.0, size=n)).tolist()
    return prompts, max_new, order, arrivals


def _assert_engine_differential(model, params, seeds, apply_mode=None,
                                num_slots=3, max_seq=48, page_size=4,
                                pool_pages=9, preempt_steps=None, spec_k=0,
                                admit_batch=3, eos_fn=None):
    """Serve each seeded schedule through the sync oracle and the engine
    (arrival-shuffled) and demand token identity, a pristine pool and
    clean serving state after every schedule. Returns the engine stats."""
    cfg = model.cfg
    sync = Server(model, params, num_slots=3, max_seq=max_seq,
                  apply_mode=apply_mode)
    eng = OverlappedServer(model, params, num_slots=num_slots,
                           max_seq=max_seq, page_size=page_size,
                           pool_pages=pool_pages, apply_mode=apply_mode,
                           preempt_steps=preempt_steps, spec_k=spec_k,
                           admit_batch=admit_batch)
    for seed in seeds:
        prompts, max_new, order, arrivals = _random_schedule(
            seed, cfg.vocab_size)
        eos = [eos_fn(p) if eos_fn else None for p in prompts]
        ra = [Request(prompt=p, max_new_tokens=m, eos_id=e)
              for p, m, e in zip(prompts, max_new, eos)]
        rb = [Request(prompt=p, max_new_tokens=m, eos_id=e)
              for p, m, e in zip(prompts, max_new, eos)]
        sync.serve(ra)
        eng.serve([rb[i] for i in order], arrival_steps=arrivals)
        for i, (a, b) in enumerate(zip(ra, rb)):
            assert a.output == b.output, (seed, i, a.output, b.output)
        if eng.pool is not None:
            eng.pool.check()
            assert eng.pool.pages_in_use == 0
        eng.state.check()
    return eng.stats


def _dense_model():
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    return model, params


def _compressed_mixtral_model():
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                        keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    return model, cp


def _sequential_generate(model, params, prompt, max_new):
    cache, _ = split_logical(model.init_cache(1, 128))
    s = len(prompt)
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt)[None]}, cache, positions=pos)
    out = [int(jnp.argmax(logits[0, -1]))]
    for t in range(max_new - 1):
        p = jnp.full((1, 1), s + t, jnp.int32)
        logits, cache = model.decode_step(
            params, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)}, cache, p)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


# ---------------------------------------------------------------------------
# tier-1: the per-row capacity argument behind batched admission prefill


def test_moe_layer_per_row_capacity_matches_stacked_b1(rng):
    """capacity_per_row=True batched MoE forward == stacking independent
    B=1 forwards, bitwise, WITH capacity drops binding.

    This is the correctness core of the engine's batched prefill
    (DESIGN.md §13): shared-capacity dispatch would let grouped rows
    compete for each other's expert slots. 64 tokens x top-2 over 8
    experts is 128 assignments against a per-row capacity of 8, so some
    expert overflows by pigeonhole — the drops are real, not vacuous."""
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    f = params["segments"][0]["slots"][0]["ffn"]
    bank = {k: v[0] for k, v in f.items() if hasattr(v, "shape")}
    b, s = 3, 64
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)

    batched, _ = moe_layer(bank, x, cfg, capacity_per_row=True)
    rows = [moe_layer(bank, x[i:i + 1], cfg)[0] for i in range(b)]
    stacked = jnp.concatenate(rows, axis=0)
    assert np.array_equal(np.asarray(batched), np.asarray(stacked))

    # sanity: the shared-capacity batched forward DOES diverge — proof the
    # scenario exercises capacity competition, so the per-row equality
    # above is not an ample-capacity tautology
    shared, _ = moe_layer(bank, x, cfg)
    assert not np.array_equal(np.asarray(shared), np.asarray(stacked))


def test_moe_layer_per_row_capacity_compressed_fused(rng):
    """Same per-row == stacked-B=1 identity on a compressed store through
    the dispatched fused path (what MoE admission prefill actually runs
    for lengths past the token-path gate)."""
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1),
        resmoe=dataclasses.replace(cfg.resmoe, method="svd", keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    # slice layer 0 out of the stacked store (center/v are nested dicts)
    store = jax.tree_util.tree_map(
        lambda a: a[0], cp["segments"][0]["slots"][0]["ffn"])
    b, s = 3, 64
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)

    batched, _ = moe_layer(store, x, cfg, apply_mode="fused",
                           capacity_per_row=True)
    rows = [moe_layer(store, x[i:i + 1], cfg, apply_mode="fused")[0]
            for i in range(b)]
    assert np.array_equal(np.asarray(batched),
                          np.asarray(jnp.concatenate(rows, axis=0)))


# ---------------------------------------------------------------------------
# tier-1: warmup precompiles the whole shape set (no in-loop compiles)


def _compile_counts(srv):
    out = {}
    for name in ("_prefill_row", "_prefill_tok", "_ostep", "_argmax_last",
                 "_decode", "_prefill"):
        fn = getattr(srv, name, None)
        if fn is not None and hasattr(fn, "_cache_size"):
            out[name] = fn._cache_size()
    if srv.drafter is not None:
        out["drafter"] = srv.drafter._step._cache_size()
    return out


def test_engine_warmup_no_recompile_attention(rng):
    """After warmup() the engine serves an open-loop trace without a
    single new XLA executable — pinned by jax's per-jit cache counters."""
    model, params = _dense_model()
    eng = OverlappedServer(model, params, num_slots=3, max_seq=48,
                           page_size=4, admit_batch=3)
    eng.warmup(max_len=8 + 6)
    before = _compile_counts(eng)
    reqs = [Request(prompt=rng.integers(0, model.cfg.vocab_size,
                                        size=(int(rng.choice([4, 6, 8])),))
                    .astype(np.int32), max_new_tokens=6) for _ in range(6)]
    eng.serve(reqs, arrival_steps=[0, 0, 1, 2, 3, 5])
    assert _compile_counts(eng) == before


@pytest.mark.engine
def test_engine_warmup_no_recompile_moe_spec():
    """MoE + spec_k engine warmup covers exact prefill lengths, all verify
    widths AND the preemption-resume lengths (forced preemption here)."""
    model, cp = _compressed_mixtral_model()
    r = np.random.default_rng(0)
    eng = OverlappedServer(model, cp, num_slots=2, max_seq=32, page_size=4,
                           pool_pages=6, apply_mode="fused_kernel", spec_k=3,
                           preempt_steps=[2], admit_batch=2)
    eng.warmup(max_len=8 + 6)
    before = _compile_counts(eng)
    reqs = [Request(prompt=r.integers(0, model.cfg.vocab_size,
                                      size=(int(r.choice([4, 6, 8])),))
                    .astype(np.int32), max_new_tokens=6) for _ in range(5)]
    eng.serve(reqs, arrival_steps=[0, 0, 1, 2, 3])
    assert _compile_counts(eng) == before
    assert eng.stats["preemptions"] >= 1


@pytest.mark.spec
def test_continuous_warmup_no_recompile_spec():
    """ContinuousServer.warmup() already covers the speculative verify
    widths (the drafter step + every [B, k] forward) — this pin keeps the
    shape-set audit honest if warmup or the spec round ever changes."""
    model, cp = _compressed_mixtral_model()
    r = np.random.default_rng(0)
    srv = ContinuousServer(model, cp, num_slots=2, max_seq=32, page_size=4,
                           pool_pages=6, apply_mode="fused_kernel", spec_k=3,
                           preempt_steps=[2])
    srv.warmup(max_len=8 + 6)
    before = _compile_counts(srv)
    reqs = [Request(prompt=r.integers(0, model.cfg.vocab_size,
                                      size=(int(r.choice([4, 6, 8])),))
                    .astype(np.int32), max_new_tokens=6) for _ in range(5)]
    srv.serve(reqs, arrival_steps=[0, 0, 1, 2, 3])
    assert _compile_counts(srv) == before
    assert srv.stats["preemptions"] >= 1


# ---------------------------------------------------------------------------
# tier-1: the stats schema both paged servers promise docs/SERVING.md


_SCHEDULER_STATS = {
    "steps": int, "preemptions": int, "tokens": int,
    "peak_pages_in_use": int, "page_util_sum": float,
    "reclaimed_pages": int, "spec_rounds": int, "spec_drafted": int,
    "spec_accepted": int, "spec_boundary_rejects": int,
}
_ENGINE_STATS = {
    "admit_groups": int, "admit_grouped_rows": int,
    "peak_admit_depth": int, "peak_ready_depth": int,
    "peak_detok_depth": int, "stalls": int,
}
_SPEC_STATS = {"rounds": int, "drafted": int, "accepted": int}
_HANDOFF_STATS = {"handoffs": int, "handoff_pages": int}
_ROUTER_STATS = {"routed_requests": int, "routed_batches": int}


def test_stats_schema_matches_serving_doc(rng):
    """Every stats counter a server emits must (a) match the schema here —
    exact key set, numeric type, non-negative — and (b) be glossed in
    docs/SERVING.md. A new counter cannot ship undocumented; a documented
    counter cannot silently disappear."""
    import pathlib

    doc = (pathlib.Path(__file__).parent.parent / "docs" /
           "SERVING.md").read_text()
    model, params = _dense_model()
    reqs = lambda: [Request(prompt=rng.integers(
        0, model.cfg.vocab_size, size=(6,)).astype(np.int32),
        max_new_tokens=3) for _ in range(3)]

    sync = Server(model, params, num_slots=2, max_seq=48)
    sync.serve(reqs())
    assert set(sync.spec_stats) == set(_SPEC_STATS)

    cont = ContinuousServer(model, params, num_slots=2, max_seq=48,
                            page_size=4)
    cont.serve(reqs())
    assert set(cont.stats) == set(_SCHEDULER_STATS)

    eng = OverlappedServer(model, params, num_slots=2, max_seq=48,
                           page_size=4, admit_batch=2)
    eng.serve(reqs())
    assert set(eng.stats) == set(_SCHEDULER_STATS) | set(_ENGINE_STATS)

    from repro.launch.router import DisaggregatedServer, Router

    dis = DisaggregatedServer(model, params, num_slots=2, max_seq=48,
                              page_size=4)
    router = Router([dis])
    router.serve(reqs())
    assert set(dis.stats) == set(_SCHEDULER_STATS) | set(_HANDOFF_STATS)
    assert set(router.stats) == set(_ROUTER_STATS)

    schema = dict(_SCHEDULER_STATS, **_ENGINE_STATS, **_HANDOFF_STATS,
                  **_ROUTER_STATS)
    for srv in (cont, eng, dis, router):
        for key, val in srv.stats.items():
            assert isinstance(val, schema[key]), (key, type(val))
            assert val >= 0, (key, val)
            assert f"`{key}`" in doc, f"stats key {key} not in SERVING.md"
    for key in _SPEC_STATS:
        assert f"`{key}`" in doc, f"spec_stats key {key} not in SERVING.md"
    # the trace ran: core counters moved and queue high-water marks are
    # bounded by what the engine was configured with
    assert eng.stats["tokens"] == 9 and eng.stats["admit_groups"] >= 1
    assert eng.stats["admit_grouped_rows"] >= eng.stats["admit_groups"]
    assert eng.stats["peak_ready_depth"] <= eng.queue_depth
    assert eng.stats["peak_detok_depth"] <= eng.queue_depth


# ---------------------------------------------------------------------------
# tier-1: constructor refusals + the fast end-to-end paths


def test_engine_refuses_sampling_and_rules():
    from repro.launch.mesh import make_mesh
    from repro.sharding import make_rules

    model, params = _dense_model()
    with pytest.raises(ValueError, match="greedy"):
        OverlappedServer(model, params, num_slots=2, max_seq=48,
                         page_size=4, greedy=False)
    rules = make_rules(make_mesh((1, 1), ("data", "model")))
    with pytest.raises(ValueError, match="rules"):
        OverlappedServer(model, params, num_slots=2, max_seq=48,
                         page_size=4, rules=rules)


def test_engine_stall_watchdog_shuts_down_and_raises(rng):
    """A wedged admission pipeline trips the watchdog: serve() raises a
    descriptive error in bounded time, shuts the background threads
    down, and drains every queue — the old teardown joined the wedged
    thread forever, so detecting the stall still hung the caller."""
    import threading
    import time

    model, params = _dense_model()
    eng = OverlappedServer(model, params, num_slots=2, max_seq=48,
                           page_size=4, admit_batch=2, stall_timeout_s=0.3)
    wedged = threading.Event()
    release = threading.Event()

    def hook(group):
        wedged.set()
        release.wait(timeout=60.0)

    eng._admit_hook = hook
    mk = lambda: [Request(prompt=rng.integers(
        0, model.cfg.vocab_size, size=(4,)).astype(np.int32),
        max_new_tokens=3) for _ in range(3)]
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="stalled: no progress"):
        eng.serve(mk())
    assert time.monotonic() - t0 < 30.0, "teardown must be bounded"
    assert wedged.is_set()
    assert eng.stats["stalls"] == 1
    assert not eng._started
    # queues drained: no prefilled group pins device buffers, no pending
    # admission leaks into a later trace
    assert eng._ready_q.qsize() == 0
    assert eng._detok_q.qsize() == 0
    assert len(eng._admitq) == 0
    assert len(eng._done_q) == 0
    # unwedge, let the abandoned thread exit, and confirm the engine
    # serves a fresh trace correctly afterwards
    release.set()
    for t in threading.enumerate():
        if t.name == "admit":
            t.join(timeout=30.0)
    eng._admit_hook = None
    ra, rb = mk(), mk()
    for a, b in zip(ra, rb):
        b.prompt = a.prompt.copy()
    Server(model, params, num_slots=2, max_seq=48).serve(ra)
    eng.serve(rb)
    assert [r.output for r in rb] == [r.output for r in ra]


def test_engine_differential_dense_fast(rng):
    """Tier-1 smoke of the full engine loop: two randomized schedules,
    token-identical to the sync oracle (the heavy spread lives in the
    `engine` tier)."""
    model, params = _dense_model()
    _assert_engine_differential(model, params, [0, 1])


def test_engine_finish_at_insert_and_reuse(rng):
    """max_new_tokens in {1, 0} finish at insertion (prefill already
    produced the only token; 0 produces none) without ever holding a
    decode slot, and one engine instance serves repeated traces."""
    model, params = _dense_model()
    eng = OverlappedServer(model, params, num_slots=2, max_seq=48,
                           page_size=4, admit_batch=3)
    for _ in range(2):
        reqs = [Request(prompt=rng.integers(0, model.cfg.vocab_size,
                                            size=(4,)).astype(np.int32),
                        max_new_tokens=n) for n in (1, 1, 5, 0)]
        eng.serve(reqs)
        assert [len(q.output) for q in reqs] == [1, 1, 5, 0]
        assert eng.pool.pages_in_use == 0


def test_record_token_times_both_servers(rng):
    """record_token_times=True stamps one monotonic wall-clock time per
    emitted token on both paged servers (the bench's latency probe)."""
    model, params = _dense_model()
    for cls, kw in ((ContinuousServer, {}),
                    (OverlappedServer, {"admit_batch": 2})):
        srv = cls(model, params, num_slots=2, max_seq=48, page_size=4,
                  record_token_times=True, **kw)
        reqs = [Request(prompt=rng.integers(0, model.cfg.vocab_size,
                                            size=(6,)).astype(np.int32),
                        max_new_tokens=4) for _ in range(3)]
        srv.serve(reqs)
        for q in reqs:
            assert len(q.token_times) == len(q.output) == 4
            assert all(b >= a for a, b in zip(q.token_times,
                                              q.token_times[1:]))


# ---------------------------------------------------------------------------
# engine tier: the heavy differential spread (scripts/ci.sh engine)


@pytest.mark.engine
def test_engine_differential_dense():
    model, params = _dense_model()
    stats = _assert_engine_differential(model, params, range(8))
    assert stats["tokens"] > 0


@pytest.mark.engine
def test_engine_differential_dense_forced_preemption():
    model, params = _dense_model()
    stats = _assert_engine_differential(model, params, [3, 11],
                                        num_slots=2, preempt_steps=[1])
    assert stats["preemptions"] >= 1


@pytest.mark.engine
@pytest.mark.parametrize("spec_k", [0, 2])
def test_engine_differential_compressed_moe(spec_k):
    """Compressed Mixtral through the fused_kernel path with forced
    preemption, at spec_k in {0, 2} — the engine runs speculative rounds
    on the synchronous path but must keep threaded-admission semantics."""
    model, cp = _compressed_mixtral_model()
    stats = _assert_engine_differential(
        model, cp, [3, 11], apply_mode="fused_kernel", num_slots=2,
        max_seq=32, page_size=4, pool_pages=6, preempt_steps=[1],
        spec_k=spec_k)
    assert stats["preemptions"] >= 1
    if spec_k:
        assert stats["spec_rounds"] >= 1


def _zoo_model(arch):
    cfg = reduced_config(arch.split("+")[0])
    if cfg.is_moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    apply_mode = None
    if arch.endswith("+resmoe"):
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=128,
                               capacity_factor=8.0),
            resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                       keep_ratio=0.5))
        apply_mode = "fused"
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    if arch.endswith("+resmoe"):
        params, _ = compress_model_params(params, cfg)
    return model, params, apply_mode


@pytest.mark.engine
@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "recurrentgemma-9b",
                                  "recurrentgemma-9b+resmoe",
                                  "deepseek-v3-671b+resmoe"])
def test_engine_differential_zoo(arch):
    """Recurrent, hybrid and MLA+MoE stacks through the engine — state
    rows splice through the same mini-cache copy as token pages — with a
    forced preemption-restore each."""
    model, params, apply_mode = _zoo_model(arch)
    stats = _assert_engine_differential(model, params, [3, 11],
                                        apply_mode=apply_mode, num_slots=2,
                                        preempt_steps=[1])
    assert stats["preemptions"] >= 1


@pytest.mark.engine
def test_engine_differential_eos_zombie():
    """EOS lands on the detokenize thread one step late: the slot keeps
    decoding as a zombie until the event drains back. Outputs must still
    cut at EOS exactly like the oracle."""
    model, params = _dense_model()

    def eos_fn(prompt):
        free = _sequential_generate(model, params, prompt, 12)
        return free[min(2, len(free) - 1)]  # fires mid-decode

    _assert_engine_differential(model, params, [5, 9], eos_fn=eos_fn)
