"""Per-layer compression plans (core/plan.py): recipes, the byte-budget
search, plan-aware segmentation, and the trimming-tier differentials.

The four TRIM_TIERS each get a parity row here (scripts/
check_parity_matrix.py): mixed rank and mixed dtype stores must serve the
same math as their uniformly-compressed equivalents, trimmed experts must
be bitwise the center_only drafter output for their tokens, and dropped
blocks must vanish from params/caches/serving consistently.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_bank
from repro.configs import reduced_config
from repro.configs.base import ModelConfig, ResMoEConfig
from repro.core.plan import (
    TRIM_TIERS,
    CompressionPlan,
    LayerRecipe,
    PlanCandidate,
    layer_candidates,
    recipe_store_bytes,
    solve_plan,
)
from repro.core.trim import (
    expert_residual_energy,
    hidden_state_similarity,
    select_dropped_blocks,
    select_dropped_experts,
)
from repro.models import transformer as tfm
from repro.models.model import (
    abstract_compressed_params,
    block_hidden_similarities,
    build_model,
    compress_model_params,
)
from repro.models.moe import moe_layer
from repro.sharding import split_logical


def _planned_cfg(plan, apply_mode="fused", **moe_kw):
    cfg = reduced_config("mixtral-8x7b")
    if moe_kw:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_kw))
    rc = dataclasses.replace(cfg.resmoe, enabled=True, method="svd",
                             apply_mode=apply_mode, plan=plan)
    return dataclasses.replace(cfg, resmoe=rc)


def _compress(plan, apply_mode="fused", **moe_kw):
    cfg = _planned_cfg(plan, apply_mode=apply_mode, **moe_kw)
    base = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, plan=None))
    values, _ = split_logical(build_model(base).init(jax.random.PRNGKey(0)))
    comp, report = compress_model_params(values, cfg)
    return cfg, jax.tree_util.tree_map(jnp.asarray, comp), report


# ---------------------------------------------------------------------------
# Recipe / plan / config validation
# ---------------------------------------------------------------------------


def test_recipe_validation():
    with pytest.raises(ValueError, match="rank"):
        LayerRecipe(rank=0)
    with pytest.raises(ValueError, match="store_dtype"):
        LayerRecipe(store_dtype="fp8")
    with pytest.raises(ValueError, match="distinct"):
        LayerRecipe(drop_experts=(1, 1))
    with pytest.raises(ValueError, match="non-negative"):
        LayerRecipe(drop_experts=(-1,))
    # canonical ordering: same drop set -> equal (hashable) recipes
    assert LayerRecipe(drop_experts=(5, 1)) == LayerRecipe(drop_experts=(1, 5))
    assert LayerRecipe().is_default
    assert not LayerRecipe(rank=3).is_default


def test_plan_validation():
    with pytest.raises(ValueError, match="at least one recipe"):
        CompressionPlan(())
    plan = CompressionPlan.uniform(3, rank=2)
    with pytest.raises(ValueError, match="3 recipes"):
        plan.validate(num_layers=4)
    with pytest.raises(ValueError, match="every block"):
        CompressionPlan(tuple(LayerRecipe(drop_block=True)
                              for _ in range(2))).validate(2)
    bad = CompressionPlan((LayerRecipe(drop_experts=(9,)), LayerRecipe()))
    with pytest.raises(ValueError, match="only 8 experts"):
        bad.validate(2, num_experts=8)
    all_dropped = CompressionPlan(
        (LayerRecipe(drop_experts=tuple(range(8))), LayerRecipe()))
    with pytest.raises(ValueError, match="drops all"):
        all_dropped.validate(2, num_experts=8)


def test_plan_json_roundtrip():
    plan = CompressionPlan((
        LayerRecipe(rank=8, drop_experts=(2, 5)),
        LayerRecipe(store_dtype="int8"),
        LayerRecipe(drop_block=True),
    ))
    assert CompressionPlan.from_json(plan.to_json()) == plan


def test_keep_ratio_validated_at_config():
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="keep_ratio"):
            ResMoEConfig(keep_ratio=bad)
    ResMoEConfig(keep_ratio=1.0)  # boundary is legal


def test_derived_rank_validated_at_model_config():
    """A keep_ratio whose derived SVD rank rounds to 0 fails at config
    construction with the minimum usable ratio named — not with a shape
    error deep inside core/residual.py."""
    cfg = reduced_config("mixtral-8x7b")
    rc = dataclasses.replace(cfg.resmoe, enabled=True, method="svd",
                             keep_ratio=1e-4)
    with pytest.raises(ValueError, match="raise keep_ratio to at least"):
        dataclasses.replace(cfg, resmoe=rc)


def test_config_rejects_non_plan_object():
    with pytest.raises(TypeError, match="CompressionPlan"):
        ResMoEConfig(plan={"layers": []})


def test_model_config_rejects_moe_recipe_on_dense_layer():
    cfg = reduced_config("granite-8b")  # dense: no MoE layers
    plan = CompressionPlan(
        (LayerRecipe(rank=4),)
        + tuple(LayerRecipe() for _ in range(cfg.num_layers - 1)))
    rc = dataclasses.replace(cfg.resmoe, plan=plan)
    with pytest.raises(ValueError, match="not a MoE layer"):
        dataclasses.replace(cfg, resmoe=rc)


def test_model_config_rejects_wrong_length_plan():
    cfg = reduced_config("mixtral-8x7b")
    rc = dataclasses.replace(cfg.resmoe, plan=CompressionPlan.uniform(2))
    with pytest.raises(ValueError, match="one recipe per ORIGINAL layer"):
        dataclasses.replace(cfg, resmoe=rc)


# ---------------------------------------------------------------------------
# Trim scoring
# ---------------------------------------------------------------------------


def test_hidden_state_similarity_bounds(rng):
    h = rng.normal(size=(2, 6, 8)).astype(np.float32)
    assert hidden_state_similarity(h, h) == pytest.approx(1.0)
    assert hidden_state_similarity(h, -h) == pytest.approx(-1.0)
    assert abs(hidden_state_similarity(h, rng.normal(size=h.shape))) < 1.0


def test_select_dropped_blocks_protect():
    sims = [0.99, 0.5, 0.98, 0.7]
    assert select_dropped_blocks(sims, 2) == (0, 2)
    assert select_dropped_blocks(sims, 2, protect=(0,)) == (2, 3)
    with pytest.raises(ValueError, match="unprotected"):
        select_dropped_blocks(sims, 4, protect=(0,))


def test_select_dropped_experts_lowest_energy(rng):
    n, f, dd = 5, 16, 12
    center = rng.normal(size=(f, dd))
    design = np.stack([center + (k + 0.1) * rng.normal(size=(f, dd))
                       for k in range(n)])
    perms = np.stack([np.arange(f)] * n)
    en = expert_residual_energy(design, center, perms)
    assert np.all(np.diff(en) > 0)  # energy grows with the noise scale
    assert select_dropped_experts(en, 2) == (0, 1)
    with pytest.raises(ValueError, match="at least one"):
        select_dropped_experts(en, 5)


def test_block_hidden_similarities_runs():
    cfg = reduced_config("mixtral-8x7b")
    values, _ = split_logical(build_model(cfg).init(jax.random.PRNGKey(0)))
    toks = np.arange(12, dtype=np.int32).reshape(1, 12) % cfg.vocab_size
    sims = block_hidden_similarities(values, cfg, toks)
    assert len(sims) == cfg.num_layers
    assert all(np.isfinite(s) and -1.0 <= s <= 1.0 for s in sims)


# ---------------------------------------------------------------------------
# Candidates + byte-budget search
# ---------------------------------------------------------------------------


def test_layer_candidates_monotone(rng):
    bank = make_bank(rng, n=4, d=16, f=24)
    cands = layer_candidates(bank, ranks=(2, 4, 8), seed=0)
    by = {(c.recipe.rank, c.recipe.store_dtype): c for c in cands}
    assert len(by) == 6  # 3 ranks x 2 dtypes
    for dt in ("fp32", "int8"):
        errs = [by[(r, dt)].error for r in (2, 4, 8)]
        byts = [by[(r, dt)].bytes for r in (2, 4, 8)]
        assert errs == sorted(errs, reverse=True), errs  # rank helps
        assert byts == sorted(byts), byts
    for r in (2, 4, 8):
        assert by[(r, "int8")].bytes < by[(r, "fp32")].bytes
        assert by[(r, "int8")].error >= by[(r, "fp32")].error


def test_layer_candidates_trim_reduces_bytes(rng):
    bank = make_bank(rng, n=4, d=16, f=24)
    full = layer_candidates(bank, ranks=(4,), dtypes=("fp32",), seed=0)[0]
    trimmed = layer_candidates(bank, ranks=(4,), dtypes=("fp32",),
                               drop_experts=(1,), seed=0)[0]
    assert trimmed.bytes < full.bytes
    assert trimmed.error >= full.error
    assert trimmed.recipe.drop_experts == (1,)


def test_recipe_store_bytes_accounting():
    segs = (("w1", 16), ("b1", 1), ("w3", 16), ("b3", 1), ("w2", 16))
    fp = recipe_store_bytes(segs, 24, 4, 6, "fp32")
    q8 = recipe_store_bytes(segs, 24, 4, 6, "int8")
    assert q8 < fp
    trimmed = recipe_store_bytes(segs, 24, 3, 6, "fp32", num_experts=4)
    assert trimmed < fp  # one expert fewer, plus the 4-int remap


def _grid(errs_bytes):
    return [PlanCandidate(LayerRecipe(rank=i + 1), b, e)
            for i, (e, b) in enumerate(errs_bytes)]


def test_solve_plan_budget_too_small():
    """An infeasible budget raises with the minimum named — returning the
    cheapest (over-budget) plan silently would violate the byte
    contract the caller is sizing hardware against."""
    cands = [_grid([(1.0, 100), (0.5, 200)]),
             _grid([(2.0, 50), (1.0, 80)])]
    with pytest.raises(ValueError,
                       match="budget infeasible, minimum is 150 bytes"):
        solve_plan(cands, 149)
    # the start seed doesn't change feasibility: the floor is what counts
    with pytest.raises(ValueError, match="budget infeasible"):
        solve_plan(cands, 149, start=[1, 1])
    # exactly at the floor is feasible
    chosen = solve_plan(cands, 150)
    assert sum(c.bytes for c in chosen) == 150


def test_budget_infeasible_surfaces_through_serve_cli():
    """serve.py --byte-budget turns the infeasibility ValueError into a
    clean SystemExit carrying the minimum-bytes message, instead of a
    traceback (or worse, serving an over-budget store)."""
    from repro.launch.serve import _solve_budget_plan

    cfg = reduced_config("mixtral-8x7b")
    params, _ = build_model(cfg).init_split(jax.random.PRNGKey(0))
    with pytest.raises(SystemExit, match="budget infeasible, minimum is"):
        _solve_budget_plan(cfg, params, 1)


def test_solve_plan_spends_budget_where_it_helps():
    # layer 0 improves 10x more per byte than layer 1
    cands = [
        _grid([(1.0, 100), (0.1, 200)]),
        _grid([(1.0, 100), (0.91, 200)]),
    ]
    chosen = solve_plan(cands, 300)
    assert [c.error for c in chosen] == [0.1, 1.0]
    assert sum(c.bytes for c in chosen) <= 300
    # a bigger budget takes both upgrades; error only improves
    chosen2 = solve_plan(cands, 400)
    assert sum(c.error for c in chosen2) <= sum(c.error for c in chosen)


def test_solve_plan_start_seed_dominates():
    """Seeded from a uniform allocation, the result never has higher total
    error (the frontier bench leans on this by-construction dominance)."""
    cands = [
        _grid([(1.0, 100), (0.4, 150), (0.2, 300)]),
        _grid([(2.0, 100), (0.6, 150), (0.5, 300)]),
    ]
    uniform = [1, 1]  # both layers at the middle candidate (300 bytes)
    chosen = solve_plan(cands, 450, start=uniform)
    tot_uniform = sum(cands[i][j].error for i, j in enumerate(uniform))
    assert sum(c.error for c in chosen) <= tot_uniform
    assert sum(c.bytes for c in chosen) <= 450


def test_solve_plan_takes_free_moves_first():
    # candidate 2 is better AND smaller than candidate 1: a free move that
    # must be taken even when the budget is already exhausted
    cands = [_grid([(1.0, 200), (0.5, 150)])]
    chosen = solve_plan(cands, 200, start=[0])
    assert chosen[0].error == 0.5


# ---------------------------------------------------------------------------
# Plan-aware segmentation
# ---------------------------------------------------------------------------


def test_trivial_plan_keeps_segmentation():
    cfg = reduced_config("mixtral-8x7b")
    planned = _planned_cfg(CompressionPlan.uniform(cfg.num_layers))
    assert tfm.layer_specs(planned) == tfm.layer_specs(cfg)
    assert tfm.build_plan(planned) == tfm.build_plan(cfg)


def test_heterogeneous_recipes_split_segments():
    cfg = reduced_config("mixtral-8x7b")
    plan = CompressionPlan((
        LayerRecipe(rank=4), LayerRecipe(rank=8), LayerRecipe(rank=4)))
    planned = _planned_cfg(plan)
    segs = tfm.build_plan(planned)
    assert sum(s.num_layers for s in segs) == 3
    # rank-4 / rank-8 / rank-4 cannot stack into one scanned segment
    assert len(segs) == 3
    # equal recipes DO stack
    plan2 = CompressionPlan.uniform(cfg.num_layers, rank=4)
    segs2 = tfm.build_plan(_planned_cfg(plan2))
    assert len(segs2) == len(tfm.build_plan(cfg))


def test_drop_block_shrinks_everything():  # PARITY: plan/block
    """A dropped block disappears from layer specs, params, caches and the
    serving layout consistently — and the compressed model still serves."""
    cfg = reduced_config("mixtral-8x7b")
    plan = CompressionPlan((
        LayerRecipe(rank=4), LayerRecipe(), LayerRecipe(drop_block=True)))
    pcfg, comp, _ = _compress(plan)
    assert len(tfm.layer_specs(pcfg)) == cfg.num_layers - 1
    assert len(tfm.mixer_layout(pcfg)) == cfg.num_layers - 1
    model = build_model(pcfg)
    cache, _ = split_logical(model.init_cache(1, 16))
    assert sum(len(c) for c in cache) == cfg.num_layers - 1
    toks = np.arange(8, dtype=np.int32).reshape(1, 8)
    logits, _ = model.forward(comp, {"tokens": toks}, apply_mode="fused")
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_mixed_rank_store_shapes():  # PARITY: plan/rank
    """Per-layer ranks land per layer (no global max-rank padding), and the
    mixed-rank model serves finitely under every dispatch mode."""
    plan = CompressionPlan((
        LayerRecipe(rank=4), LayerRecipe(rank=12), LayerRecipe(rank=4)))
    pcfg, comp, _ = _compress(plan)
    ranks = []
    for seg in comp["segments"]:
        for slot in seg["slots"]:
            f = slot.get("ffn")
            if isinstance(f, dict) and "u" in f:
                ranks.append(int(np.asarray(f["u"]).shape[-1]))
    assert sorted(ranks) == [4, 4, 12]
    model = build_model(pcfg)
    toks = np.arange(8, dtype=np.int32).reshape(1, 8)
    for mode in ("fused", "fused_kernel", "restored"):
        logits, _ = model.forward(comp, {"tokens": toks}, apply_mode=mode)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), mode


def test_mixed_dtype_store_matches_uniform_layers():  # PARITY: plan/dtype
    """In a mixed fp32/int8 plan, each layer's store is identical to the
    same layer under a UNIFORM plan of its own dtype — per-layer dtype is
    exactly per-layer quantization, not a different compression."""
    mixed = CompressionPlan((
        LayerRecipe(rank=6, store_dtype="fp32"),
        LayerRecipe(rank=6, store_dtype="int8"),
        LayerRecipe(rank=6, store_dtype="fp32"),
    ))
    _, comp_mixed, _ = _compress(mixed)
    _, comp_fp, _ = _compress(CompressionPlan.uniform(3, rank=6))
    _, comp_q8, _ = _compress(
        CompressionPlan.uniform(3, rank=6, store_dtype="int8"))

    def stores(tree):
        out = []
        for seg in tree["segments"]:
            for slot in seg["slots"]:
                f = slot.get("ffn")
                if isinstance(f, dict) and "center" in f:
                    reps = (np.asarray(f["u"]).shape[0]
                            if np.asarray(f["u"]).ndim == 4 else 1)
                    for r in range(reps):
                        out.append(jax.tree_util.tree_map(
                            lambda x, r=r: np.asarray(x)[r]
                            if np.asarray(x).ndim == 4 or (
                                isinstance(x, np.ndarray) and False)
                            else np.asarray(x), f))
        return out

    sm = stores(comp_mixed)
    sf = stores(comp_fp)
    sq = stores(comp_q8)
    assert len(sm) == 3
    for i, ref in ((0, sf), (1, sq), (2, sf)):
        a, b = sm[i], ref[i]
        assert set(a) == set(b), i
        np.testing.assert_array_equal(np.asarray(a["u"]), np.asarray(b["u"]))
        for k in a["v"]:
            np.testing.assert_array_equal(np.asarray(a["v"][k]),
                                          np.asarray(b["v"][k]))
    assert "u_scale" in sm[1] and "u_scale" not in sm[0]


def test_trimmed_experts_bitwise_center_only():  # PARITY: plan/expert
    """Tokens routed ONLY to dropped experts are bitwise-equal to the
    center_only drafter output — dropped experts resolve to the shared
    center with their full gate mass, nothing else contributes."""
    cfg = reduced_config("mixtral-8x7b")
    drop = (0, 1, 2, 3, 4, 5)  # top_k=2 over 8 experts: drops are common
    plan = CompressionPlan(
        tuple(LayerRecipe(rank=6, drop_experts=drop)
              for _ in range(cfg.num_layers)))
    pcfg, comp, _ = _compress(plan)
    store = None
    for seg in comp["segments"]:
        for slot in seg["slots"]:
            f = slot.get("ffn")
            if isinstance(f, dict) and "expert_map" in f:
                # strip the scanned leading axis (if any) from every leaf
                stacked = np.asarray(f["u"]).ndim == 4
                store = jax.tree_util.tree_map(
                    lambda x: jnp.asarray(np.asarray(x)[0] if stacked
                                          else np.asarray(x)), f)
                break
        if store is not None:
            break
    assert store is not None
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 16, cfg.d_model)).astype(np.float32))
    for mode in ("fused", "fused_kernel", "fused_token", "restored"):
        y, aux = moe_layer(store, x, pcfg, apply_mode=mode)
        y_center, _ = moe_layer(store, x, pcfg, apply_mode="center_only")
        ids = np.asarray(aux["expert_ids"]) if "expert_ids" in aux else None
        emap = np.asarray(store["expert_map"])
        if ids is not None:
            fully_dropped = (emap[ids] < 0).all(-1).reshape(16)
        else:
            # recompute routing on the host to find fully-dropped tokens
            from repro.models.moe import route
            ids, _, _ = route(store, x.reshape(16, -1), pcfg.moe)
            fully_dropped = (emap[np.asarray(ids)] < 0).all(-1)
        assert fully_dropped.any(), "test needs at least one dropped token"
        ya = np.asarray(y).reshape(16, -1)
        yb = np.asarray(y_center).reshape(16, -1)
        np.testing.assert_array_equal(ya[fully_dropped], yb[fully_dropped],
                                      err_msg=mode)


# ---------------------------------------------------------------------------
# Abstract store parity
# ---------------------------------------------------------------------------


def test_abstract_matches_concrete_planned_store():
    """eval_shape'd plan store == the real compressed tree, leaf for leaf
    (shapes + presence of expert_map / scales), so the dry-run lowers the
    heterogeneous serving graph faithfully."""
    plan = CompressionPlan((
        LayerRecipe(rank=4, drop_experts=(1, 5)),
        LayerRecipe(rank=6, store_dtype="int8"),
        LayerRecipe(rank=4, drop_experts=(1, 5)),
    ))
    pcfg, comp, _ = _compress(plan)
    values, axes = abstract_compressed_params(pcfg)
    flat_a = {k: v for k, v in jax.tree_util.tree_flatten_with_path(
        values["segments"])[0]}
    flat_c = {k: v for k, v in jax.tree_util.tree_flatten_with_path(
        jax.tree_util.tree_map(np.asarray, comp["segments"]))[0]}
    assert set(map(str, flat_a)) == set(map(str, flat_c))
    for k, spec in flat_a.items():
        got = flat_c[k]
        assert tuple(spec.shape) == tuple(np.shape(got)), (str(k), spec.shape,
                                                           np.shape(got))
    # axes tree mirrors values structurally
    jax.tree_util.tree_map(lambda v, a: None, values, axes,
                           is_leaf=lambda x: isinstance(x, tuple))
