"""Routed serving: ``Router`` over N replicas == one server, token-for-token.

launch/router.py is the multi-host front door (DESIGN.md §14): a
deterministic assignment policy partitions the trace across independent
replica servers, and an opt-in prefill/decode disaggregated pair hands
finished prefills to the decode server as a block-table row plus page
copy. None of it may change greedy outputs, so the differentials here
pin the routed union — and the disaggregated server, with forced
mid-request preemption — against the slot-synchronous ``Server`` oracle
across randomized schedules. The pure-python pieces (assignment
determinism, constructor refusals, device splitting) are unit-tested
alongside.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.router import (
    DisaggregatedServer,
    Router,
    assign_requests,
    build_replicas,
)
from repro.launch.serve import ContinuousServer, Request, Server
from repro.models import build_model, compress_model_params
from repro.sharding import split_devices


def _random_schedule(seed, vocab, n_lo=3, n_hi=6, max_new_hi=7):
    """Same trace family as test_serve/test_engine: a few prompts of
    length {4, 6, 8}, random budgets, permuted order, Poisson arrivals."""
    r = np.random.default_rng(seed)
    n = int(r.integers(n_lo, n_hi + 1))
    prompts = [r.integers(0, vocab, size=(int(r.choice([4, 6, 8])),))
               .astype(np.int32) for _ in range(n)]
    max_new = [int(r.integers(1, max_new_hi)) for _ in range(n)]
    order = r.permutation(n)
    arrivals = np.sort(r.poisson(1.0, size=n)).tolist()
    return prompts, max_new, order, arrivals


def _dense_model():
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    return model, params


def _compressed_mixtral_model():
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                        keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    return model, cp


def _assert_routed_differential(model, params, seeds, *, num_replicas=2,
                                apply_mode=None, disaggregate=False,
                                preempt_steps=None, policy="least_loaded"):
    """Serve each seeded schedule through the sync oracle and through a
    Router over ``num_replicas`` independent replicas (arrival-shuffled)
    and demand per-request token identity plus pristine pools/state on
    every replica. Returns the router for stats assertions."""
    cfg = model.cfg
    sync = Server(model, params, num_slots=3, max_seq=48,
                  apply_mode=apply_mode)
    replicas = build_replicas(
        model, params, num_replicas, disaggregate=disaggregate,
        num_slots=2, max_seq=48, page_size=4, pool_pages=9,
        apply_mode=apply_mode, preempt_steps=preempt_steps)
    router = Router(replicas, policy=policy)
    for seed in seeds:
        prompts, max_new, order, arrivals = _random_schedule(
            seed, cfg.vocab_size)
        ra = [Request(prompt=p, max_new_tokens=m)
              for p, m in zip(prompts, max_new)]
        rb = [Request(prompt=p, max_new_tokens=m)
              for p, m in zip(prompts, max_new)]
        sync.serve(ra)
        router.serve([rb[i] for i in order], arrival_steps=arrivals)
        for i, (a, b) in enumerate(zip(ra, rb)):
            assert a.output == b.output, (seed, i, a.output, b.output)
        for rep in router.replicas:
            if rep.pool is not None:
                rep.pool.check()
                assert rep.pool.pages_in_use == 0
            rep.state.check()
    return router


# ---------------------------------------------------------------------------
# assignment policies: pure, deterministic, balanced


def test_assign_requests_round_robin_and_determinism():
    reqs = [Request(prompt=np.zeros(4, np.int32), max_new_tokens=3)
            for _ in range(7)]
    assert assign_requests(reqs, 3, "round_robin") == [0, 1, 2, 0, 1, 2, 0]
    a = assign_requests(reqs, 3, "least_loaded")
    assert a == assign_requests(reqs, 3, "least_loaded")
    # every replica gets work when requests outnumber replicas
    assert set(a) == {0, 1, 2}


def test_assign_requests_least_loaded_balances_cost():
    # one heavy request then many light ones: the heavy replica should
    # be skipped until the others catch up on estimated tokens
    heavy = Request(prompt=np.zeros(8, np.int32), max_new_tokens=100)
    light = [Request(prompt=np.zeros(4, np.int32), max_new_tokens=1)
             for _ in range(4)]
    a = assign_requests([heavy] + light, 2, "least_loaded")
    assert a[0] == 0  # ties break to the lowest index
    assert a[1:] == [1, 1, 1, 1]


def test_assign_requests_validation():
    reqs = [Request(prompt=np.zeros(4, np.int32), max_new_tokens=1)]
    with pytest.raises(ValueError, match="at least one replica"):
        assign_requests(reqs, 0)
    with pytest.raises(ValueError, match="unknown routing policy"):
        assign_requests(reqs, 2, "fastest_finger")


def test_router_constructor_and_serve_validation():
    with pytest.raises(ValueError, match="at least one replica"):
        Router([])
    with pytest.raises(ValueError, match="unknown routing policy"):
        Router([object()], policy="nope")

    class _Boom:
        def serve(self, requests, arrival_steps=None):
            raise RuntimeError("kaboom")

    router = Router([_Boom()])
    reqs = [Request(prompt=np.zeros(4, np.int32), max_new_tokens=1)]
    with pytest.raises(ValueError, match="arrival_steps must match"):
        router.serve(reqs, arrival_steps=[0, 1])
    with pytest.raises(RuntimeError, match="replica 0 failed serving 1"):
        router.serve(reqs)


def test_build_replicas_validation():
    with pytest.raises(ValueError, match="at least one replica"):
        build_replicas(None, None, 0)
    with pytest.raises(ValueError, match="incompatible"):
        build_replicas(None, None, 2, disaggregate=True, overlapped=True)
    with pytest.raises(ValueError, match="one entry per replica"):
        build_replicas(None, None, 2, rules_list=[None])


def test_split_devices_edges():
    devs = list(range(8))
    assert split_devices(devs, 2) == [[0, 1, 2, 3], [4, 5, 6, 7]]
    assert split_devices(devs, 3, group_size=2) == [[0, 1], [2, 3], [4, 5]]
    with pytest.raises(ValueError):
        split_devices(devs, 0)
    with pytest.raises(ValueError):
        split_devices(devs, 16)  # groups would be empty
    with pytest.raises(ValueError):
        split_devices(devs, 3, group_size=3)  # needs 9 devices


# ---------------------------------------------------------------------------
# routed differentials: token identity across replicas


def test_router_differential_dense():
    """6 randomized schedules over 2 replicas: the routed union is
    per-request token-identical to one sync server, and every replica's
    pool comes back pristine. Both policies covered."""
    model, params = _dense_model()
    r = _assert_routed_differential(model, params, range(3))
    assert r.stats["routed_batches"] == 3
    assert r.stats["routed_requests"] >= 9
    agg = r.aggregate_stats()
    assert agg["replicas"] == 2
    assert agg["tokens"] > 0 and len(agg["per_replica"]) == 2
    _assert_routed_differential(model, params, range(3, 6),
                                policy="round_robin")


def test_router_differential_forced_preemption():
    """Routed replicas under forced mid-request eviction: the
    preempt/recompute-restore path must stay invisible to outputs even
    when it fires inside a routed sub-trace."""
    model, params = _dense_model()
    r = _assert_routed_differential(model, params, range(2),
                                    preempt_steps=[2, 5])
    total = sum(rep.stats["preemptions"] for rep in r.replicas)
    assert total >= 1, "forced preemption never fired — schedule too small"


def test_disaggregated_server_differential():
    """Prefill/decode disaggregation alone (1 replica): every admission
    arrives as a worker handoff, outputs stay token-identical, and the
    handoff page accounting matches the prompts served."""
    model, params = _dense_model()
    r = _assert_routed_differential(model, params, range(2),
                                    num_replicas=1, disaggregate=True)
    rep = r.replicas[0]
    assert isinstance(rep, DisaggregatedServer)
    assert rep.stats["handoffs"] > 0
    assert rep.stats["handoff_pages"] >= rep.stats["handoffs"]
    # the worker ran one prefill per handoff (warmup counts are reset)
    assert rep.prefiller.stats["prefills"] == rep.stats["handoffs"]


def test_disaggregated_router_preemption_mixtral():
    """The full topology on a compressed MoE: 2 disaggregated replicas
    with forced preemption — resumes re-enter through the prefill worker
    and must remain token-identical to the oracle."""
    model, params = _compressed_mixtral_model()
    r = _assert_routed_differential(model, params, range(2),
                                    disaggregate=True,
                                    apply_mode="restored",
                                    preempt_steps=[2])
    assert sum(rep.stats["preemptions"] for rep in r.replicas) >= 1
    assert sum(rep.stats["handoffs"] for rep in r.replicas) > 0
