"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    bcsr_from_residual,
    block_sparse_matmul,
    grouped_lowrank_matmul,
    lowrank_restore_matmul,
    prepare_bcsr,
    resmoe_block_apply,
    resmoe_grouped_svd_apply,
    resmoe_svd_apply,
)
from repro.kernels.ref import (
    block_sparse_matmul_ref,
    grouped_expert_bank_ref,
    grouped_lowrank_matmul_ref,
    lowrank_restore_matmul_ref,
)


@pytest.mark.parametrize("m,k,n,r", [
    (128, 128, 128, 16),
    (256, 384, 512, 64),
    (100, 200, 300, 33),   # unaligned -> padding path
    (8, 512, 128, 1),      # tiny rank
    (64, 128, 896, 130),   # rank > 128 -> multi-tile R
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lowrank_kernel_allclose(m, k, n, r, dtype, rng):
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)), dtype)
    a = jnp.asarray(rng.normal(size=(k, r)), dtype)
    b = jnp.asarray(rng.normal(size=(r, n)), dtype)
    y = lowrank_restore_matmul(x, w, a, b, interpret=True, out_dtype=jnp.float32)
    yref = lowrank_restore_matmul_ref(x, w, a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    scale = float(jnp.max(jnp.abs(yref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - yref))) / scale < tol


@pytest.mark.parametrize("m,k,n,bk,bn,density", [
    (128, 256, 384, 128, 128, 0.4),
    (64, 256, 512, 8, 128, 0.25),
    (200, 128, 256, 128, 128, 0.1),
    (32, 64, 128, 8, 128, 1.0),
    (16, 512, 640, 8, 128, 0.05),  # very sparse -> column padding path
])
def test_block_sparse_kernel_allclose(m, k, n, bk, bn, density, rng):
    nkb, nnb = k // bk, n // bn
    mask = rng.random((nkb, nnb)) < density
    idx = np.argwhere(mask)
    if len(idx) == 0:
        idx = np.array([[0, 0]])
    vals = rng.normal(size=(len(idx), bk, bn)).astype(np.float32)
    br, bc = idx[:, 0].astype(np.int32), idx[:, 1].astype(np.int32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    yref = block_sparse_matmul_ref(x, vals, br, bc, n)
    v2, br2, bc2, first = prepare_bcsr(vals, br, bc, nnb)
    y = block_sparse_matmul(
        x, jnp.asarray(v2), jnp.asarray(br2), jnp.asarray(bc2),
        jnp.asarray(first), n=n, interpret=True,
    )
    scale = float(jnp.max(jnp.abs(yref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - yref))) / scale < 1e-5


def test_ops_svd_apply_matches_restore(rng):
    from repro.core.residual import compress_svd

    K, N, T = 96, 160, 48
    center = rng.normal(size=(K, N)).astype(np.float32)
    dw = (rng.normal(size=(K, N)) * 0.1).astype(np.float32)
    x = rng.normal(size=(T, K)).astype(np.float32)
    res = compress_svd(dw.T, keep_ratio=0.5)  # design layout [N, K]
    y = resmoe_svd_apply(jnp.asarray(x), jnp.asarray(center),
                         jnp.asarray(res.u), jnp.asarray(res.v), interpret=True)
    yref = x @ (center + (res.u @ res.v).T)
    np.testing.assert_allclose(np.asarray(y), yref, rtol=2e-4, atol=2e-4)


def test_ops_block_apply_matches_restore(rng):
    from repro.core.residual import prune_block

    K, N, T = 64, 256, 32
    center = rng.normal(size=(K, N)).astype(np.float32)
    delta = (rng.normal(size=(K, N)) * 0.1).astype(np.float32)
    res = prune_block(delta, keep_ratio=0.3, block_shape=(8, 128))
    bcsr = bcsr_from_residual(res, n_cols=res.shape[1])
    x = rng.normal(size=(T, K)).astype(np.float32)
    y = resmoe_block_apply(jnp.asarray(x), jnp.asarray(center), bcsr, interpret=True)
    yref = x @ (center + res.to_dense()[:K, :N])
    np.testing.assert_allclose(np.asarray(y), yref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("e,c,k,n,r", [
    (4, 128, 128, 128, 16),
    (8, 64, 256, 384, 32),
    (3, 100, 200, 300, 33),   # every dim unaligned -> padding path
    (2, 8, 512, 128, 1),      # tiny capacity + tiny rank
    (5, 16, 96, 640, 130),    # rank > 128 -> multi-tile R
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_kernel_allclose(e, c, k, n, r, dtype, rng):
    xg = jnp.asarray(rng.normal(size=(e, c, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)), dtype)
    a = jnp.asarray(rng.normal(size=(e, k, r)), dtype)
    b = jnp.asarray(rng.normal(size=(e, r, n)), dtype)
    y = grouped_lowrank_matmul(xg, w, a, b, interpret=True,
                               out_dtype=jnp.float32)
    yref = grouped_lowrank_matmul_ref(xg, w, a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    scale = float(jnp.max(jnp.abs(yref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - yref))) / scale < tol


def test_grouped_kernel_multi_k_step(rng):
    """Force several k blocks: the shared-center accumulator must survive
    the expert grid axis sitting between (m, n) and k."""
    e, c, k, n, r = 4, 48, 384, 256, 40
    xg = jnp.asarray(rng.normal(size=(e, c, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(e, k, r)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(e, r, n)), jnp.float32)
    y = grouped_lowrank_matmul(xg, w, a, b, bk=128, interpret=True)
    yref = grouped_lowrank_matmul_ref(xg, w, a, b)
    scale = float(jnp.max(jnp.abs(yref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - yref))) / scale < 1e-4


def test_grouped_matches_single_expert_kernel(rng):
    """The grouped kernel over a bank == the single-expert kernel per slice."""
    e, c, k, n, r = 3, 32, 128, 160, 24
    xg = jnp.asarray(rng.normal(size=(e, c, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(e, k, r)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(e, r, n)), jnp.float32)
    y = grouped_lowrank_matmul(xg, w, a, b, interpret=True)
    for i in range(e):
        yi = lowrank_restore_matmul(xg[i], w, a[i], b[i], interpret=True)
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(yi),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("glu", [True, False])
def test_grouped_bank_glu_oracle(glu, rng):
    """Full expert-FFN bank (both segments, GLU on/off) vs the jnp oracle,
    composed exactly as moe.py's fused_kernel path composes the kernel."""
    import jax

    e, c, d, f, r = 3, 24, 96, 160, 20
    xg = jnp.asarray(rng.normal(size=(e, c, d)), jnp.float32)
    center = {"w1": jnp.asarray(rng.normal(size=(d, f)), jnp.float32),
              "w2": jnp.asarray(rng.normal(size=(f, d)), jnp.float32)}
    if glu:
        center["w3"] = jnp.asarray(rng.normal(size=(d, f)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(e, f, r)), jnp.float32)
    v = {s: jnp.asarray(rng.normal(size=(e, r, dd)), jnp.float32)
         for s, dd in (("w1", d), ("w3", d), ("w2", d)) if glu or s != "w3"}

    ut = jnp.swapaxes(u, 1, 2)
    h = jax.nn.silu(grouped_lowrank_matmul(
        xg, center["w1"], jnp.swapaxes(v["w1"], 1, 2), ut, interpret=True))
    if glu:
        h = h * grouped_lowrank_matmul(
            xg, center["w3"], jnp.swapaxes(v["w3"], 1, 2), ut, interpret=True)
    y = grouped_lowrank_matmul(h, center["w2"], u, v["w2"], interpret=True)

    yref = grouped_expert_bank_ref(xg, center, u, v, activation="silu")
    scale = float(jnp.max(jnp.abs(yref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - yref))) / scale < 1e-4


def test_ops_grouped_svd_apply_matches_restore(rng):
    """resmoe_grouped_svd_apply on per-expert SVD stores == explicit
    per-expert restore."""
    from repro.core.residual import compress_svd

    e, k, n, t = 3, 96, 160, 24
    center = rng.normal(size=(k, n)).astype(np.float32)
    xg = rng.normal(size=(e, t, k)).astype(np.float32)
    us, vs, refs = [], [], []
    for i in range(e):
        dw = (rng.normal(size=(k, n)) * 0.1).astype(np.float32)
        res = compress_svd(dw.T, keep_ratio=0.5)  # design layout [N, K]
        us.append(res.u)
        vs.append(res.v)
        refs.append(xg[i] @ (center + (res.u @ res.v).T))
    y = resmoe_grouped_svd_apply(
        jnp.asarray(xg), jnp.asarray(center),
        jnp.asarray(np.stack(us)), jnp.asarray(np.stack(vs)), interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.stack(refs),
                               rtol=2e-4, atol=2e-4)


def test_lowrank_kernel_hypothesis(rng):
    """Random shape sweep (lightweight hypothesis-style fuzz)."""
    for _ in range(10):
        m = int(rng.integers(1, 200))
        k = int(rng.integers(1, 300))
        n = int(rng.integers(1, 300))
        r = int(rng.integers(1, 64))
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        a = jnp.asarray(rng.normal(size=(k, r)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(r, n)), jnp.float32)
        y = lowrank_restore_matmul(x, w, a, b, interpret=True)
        yref = lowrank_restore_matmul_ref(x, w, a, b)
        scale = float(jnp.max(jnp.abs(yref))) + 1e-9
        assert float(jnp.max(jnp.abs(y - yref))) / scale < 1e-4
