"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    bcsr_from_residual,
    block_sparse_matmul,
    lowrank_restore_matmul,
    prepare_bcsr,
    resmoe_block_apply,
    resmoe_svd_apply,
)
from repro.kernels.ref import block_sparse_matmul_ref, lowrank_restore_matmul_ref


@pytest.mark.parametrize("m,k,n,r", [
    (128, 128, 128, 16),
    (256, 384, 512, 64),
    (100, 200, 300, 33),   # unaligned -> padding path
    (8, 512, 128, 1),      # tiny rank
    (64, 128, 896, 130),   # rank > 128 -> multi-tile R
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lowrank_kernel_allclose(m, k, n, r, dtype, rng):
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)), dtype)
    a = jnp.asarray(rng.normal(size=(k, r)), dtype)
    b = jnp.asarray(rng.normal(size=(r, n)), dtype)
    y = lowrank_restore_matmul(x, w, a, b, interpret=True, out_dtype=jnp.float32)
    yref = lowrank_restore_matmul_ref(x, w, a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    scale = float(jnp.max(jnp.abs(yref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - yref))) / scale < tol


@pytest.mark.parametrize("m,k,n,bk,bn,density", [
    (128, 256, 384, 128, 128, 0.4),
    (64, 256, 512, 8, 128, 0.25),
    (200, 128, 256, 128, 128, 0.1),
    (32, 64, 128, 8, 128, 1.0),
    (16, 512, 640, 8, 128, 0.05),  # very sparse -> column padding path
])
def test_block_sparse_kernel_allclose(m, k, n, bk, bn, density, rng):
    nkb, nnb = k // bk, n // bn
    mask = rng.random((nkb, nnb)) < density
    idx = np.argwhere(mask)
    if len(idx) == 0:
        idx = np.array([[0, 0]])
    vals = rng.normal(size=(len(idx), bk, bn)).astype(np.float32)
    br, bc = idx[:, 0].astype(np.int32), idx[:, 1].astype(np.int32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    yref = block_sparse_matmul_ref(x, vals, br, bc, n)
    v2, br2, bc2, first = prepare_bcsr(vals, br, bc, nnb)
    y = block_sparse_matmul(
        x, jnp.asarray(v2), jnp.asarray(br2), jnp.asarray(bc2),
        jnp.asarray(first), n=n, interpret=True,
    )
    scale = float(jnp.max(jnp.abs(yref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - yref))) / scale < 1e-5


def test_ops_svd_apply_matches_restore(rng):
    from repro.core.residual import compress_svd

    K, N, T = 96, 160, 48
    center = rng.normal(size=(K, N)).astype(np.float32)
    dw = (rng.normal(size=(K, N)) * 0.1).astype(np.float32)
    x = rng.normal(size=(T, K)).astype(np.float32)
    res = compress_svd(dw.T, keep_ratio=0.5)  # design layout [N, K]
    y = resmoe_svd_apply(jnp.asarray(x), jnp.asarray(center),
                         jnp.asarray(res.u), jnp.asarray(res.v), interpret=True)
    yref = x @ (center + (res.u @ res.v).T)
    np.testing.assert_allclose(np.asarray(y), yref, rtol=2e-4, atol=2e-4)


def test_ops_block_apply_matches_restore(rng):
    from repro.core.residual import prune_block

    K, N, T = 64, 256, 32
    center = rng.normal(size=(K, N)).astype(np.float32)
    delta = (rng.normal(size=(K, N)) * 0.1).astype(np.float32)
    res = prune_block(delta, keep_ratio=0.3, block_shape=(8, 128))
    bcsr = bcsr_from_residual(res, n_cols=res.shape[1])
    x = rng.normal(size=(T, K)).astype(np.float32)
    y = resmoe_block_apply(jnp.asarray(x), jnp.asarray(center), bcsr, interpret=True)
    yref = x @ (center + res.to_dense()[:K, :N])
    np.testing.assert_allclose(np.asarray(y), yref, rtol=2e-4, atol=2e-4)


def test_lowrank_kernel_hypothesis(rng):
    """Random shape sweep (lightweight hypothesis-style fuzz)."""
    for _ in range(10):
        m = int(rng.integers(1, 200))
        k = int(rng.integers(1, 300))
        n = int(rng.integers(1, 300))
        r = int(rng.integers(1, 64))
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        a = jnp.asarray(rng.normal(size=(k, r)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(r, n)), jnp.float32)
        y = lowrank_restore_matmul(x, w, a, b, interpret=True)
        yref = lowrank_restore_matmul_ref(x, w, a, b)
        scale = float(jnp.max(jnp.abs(yref))) + 1e-9
        assert float(jnp.max(jnp.abs(y - yref))) / scale < 1e-4
