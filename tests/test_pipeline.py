"""Data pipeline: determinism, host sharding, shapes, prefetch."""
import numpy as np

from repro.configs import reduced_config
from repro.data import DataConfig, SyntheticLMDataset, make_pipeline


def test_deterministic_by_index():
    cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=4)
    ds1 = SyntheticLMDataset(cfg)
    ds2 = SyntheticLMDataset(cfg)
    for i in (0, 3, 17):
        b1, b2 = ds1.batch(i), ds2.batch(i)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    ds = SyntheticLMDataset(DataConfig(vocab_size=64, seq_len=16, global_batch=2))
    b = ds.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_slicing_distinct():
    host0 = SyntheticLMDataset(DataConfig(64, 16, 4, num_hosts=2, host_index=0))
    host1 = SyntheticLMDataset(DataConfig(64, 16, 4, num_hosts=2, host_index=1))
    b0, b1 = host0.batch(0), host1.batch(0)
    assert b0["tokens"].shape == (2, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_structure_learnable():
    """85% of transitions follow the fixed map — a model can learn this."""
    ds = SyntheticLMDataset(DataConfig(vocab_size=64, seq_len=256, global_batch=4))
    b = ds.batch(0)
    toks = b["tokens"]
    nxt = ds._mix[toks % 257] % 64
    match = (np.roll(toks, -1, axis=1)[:, :-1] == nxt[:, :-1]).mean()
    assert match > 0.7


def test_frontends():
    vcfg = reduced_config("paligemma-3b")
    ds = make_pipeline(vcfg, 16, 2)
    b = ds.batch(0)
    assert b["patch_embeddings"].shape == (2, vcfg.num_prefix_embeddings, vcfg.d_model)
    assert b["tokens"].shape[1] == 16 - vcfg.num_prefix_embeddings

    acfg = reduced_config("musicgen-medium")
    ds = make_pipeline(acfg, 16, 2)
    b = ds.batch(0)
    assert b["frame_embeddings"].shape == (2, 16, acfg.d_model)
    assert b["labels"].shape == (2, 16, acfg.num_codebooks)


def test_prefetch_iterator():
    ds = SyntheticLMDataset(DataConfig(64, 8, 2, prefetch=2))
    it = ds.iterate()
    first = next(it)
    second = next(it)
    np.testing.assert_array_equal(first["tokens"], ds.batch(0)["tokens"])
    np.testing.assert_array_equal(second["tokens"], ds.batch(1)["tokens"])
