"""Property-based tests (hypothesis) on the system's core invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.compress import compress_bank, design_matrices, restored_bank
from repro.core.ot import ot_permutation
from repro.core.residual import prune_unstructured, svd_rank_for_ratio

_settings = dict(max_examples=20, deadline=None)


@given(
    n=st.integers(2, 5),
    p_i=st.integers(2, 12),
    d=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
@settings(**_settings)
def test_ot_permutation_recovery(n, p_i, d, seed):
    """For any matrix with distinct rows, OT alignment of a shuffled copy
    recovers the shuffle exactly."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(p_i, d)) * 3
    perm = rng.permutation(p_i)
    got = ot_permutation(x[perm], x)
    np.testing.assert_allclose(x[perm][got], x)


@given(
    m=st.integers(1, 24),
    n=st.integers(1, 24),
    ratio=st.floats(0.05, 1.0),
    seed=st.integers(0, 10_000),
)
@settings(**_settings)
def test_prune_monotone_error(m, n, ratio, seed):
    """Pruning error is monotone non-increasing in keep ratio, and exact-k."""
    rng = np.random.default_rng(seed)
    d = rng.normal(size=(m, n)).astype(np.float32)
    c1 = prune_unstructured(d, ratio)
    c2 = prune_unstructured(d, min(1.0, ratio + 0.3))
    e1 = ((c1.to_dense() - d) ** 2).sum()
    e2 = ((c2.to_dense() - d) ** 2).sum()
    assert e2 <= e1 + 1e-6
    assert c1.nnz == max(1, round(ratio * d.size))


@given(
    m=st.integers(2, 64),
    n=st.integers(2, 64),
    ratio=st.floats(0.05, 0.9),
)
@settings(**_settings)
def test_svd_rank_positive_and_bounded(m, n, ratio):
    r = svd_rank_for_ratio(m, n, ratio)
    assert 1 <= r
    # never more params than the requested budget + one rank step
    assert r * (m + n) <= ratio * m * n + (m + n)


@given(seed=st.integers(0, 1000), keep=st.floats(0.1, 0.9))
@settings(max_examples=8, deadline=None)
def test_resmoe_error_bounded_by_center_distance(seed, keep):
    """The ResMoE error never exceeds the uncompressed residual energy
    (compressing the residual can only reduce what's stored, and keeping
    top-magnitude entries keeps error <= full residual energy)."""
    rng = np.random.default_rng(seed)
    n, d, f = 4, 6, 8
    bank = {
        "w1": rng.normal(size=(n, d, f)).astype(np.float32),
        "w3": rng.normal(size=(n, d, f)).astype(np.float32),
        "w2": rng.normal(size=(n, f, d)).astype(np.float32),
    }
    design = design_matrices(bank)
    comp = compress_bank(bank, method="up", keep_ratio=keep)
    err = comp.approximation_error(design)
    # residual energy with NO compression of deltas:
    full_energy = 0.0
    for k in range(n):
        dd = design[k][comp.perms[k]] - comp.center
        full_energy += (dd * dd).sum()
    full_energy /= n * design.shape[1]
    assert err <= full_energy + 1e-9


@given(seed=st.integers(0, 1000))
@settings(max_examples=6, deadline=None)
def test_restored_expert_function_invariance(seed):
    """Restore at keep=1 (UP) preserves every expert as a function for any
    random bank — the permutation-invariance property end-to-end."""
    import jax

    rng = np.random.default_rng(seed)
    n, d, f = 3, 5, 7
    bank = {
        "w1": rng.normal(size=(n, d, f)).astype(np.float32),
        "w3": rng.normal(size=(n, d, f)).astype(np.float32),
        "w2": rng.normal(size=(n, f, d)).astype(np.float32),
    }
    comp = compress_bank(bank, method="up", keep_ratio=1.0)
    rb = restored_bank(comp, {k: v[0] for k, v in bank.items()})
    x = rng.normal(size=(4, d)).astype(np.float32)

    def f_expert(w, x):
        h = jax.nn.silu(x @ w["w1"]) * (x @ w["w3"])
        return np.asarray(h @ w["w2"])

    for k in range(n):
        a = f_expert({m: bank[m][k] for m in bank}, x)
        b = f_expert({m: rb[m][k] for m in rb}, x)
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


@given(
    t=st.integers(1, 40),
    e=st.integers(2, 8),
    k=st.integers(1, 2),
    seed=st.integers(0, 5000),
)
@settings(**_settings)
def test_dispatch_conservation(t, e, k, seed):
    """Every kept (token, expert) pair lands in exactly one slot and is
    recovered by combine with weight 1."""
    import jax.numpy as jnp

    from repro.models.moe import combine_tokens, dispatch_tokens, make_dispatch

    rng = np.random.default_rng(seed)
    k = min(k, e)
    ids = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    cap = t * k
    token_idx, dest, keep, sort_idx = make_dispatch(ids, e, cap)
    x = jnp.asarray(rng.normal(size=(t, 4)), jnp.float32)
    xg = dispatch_tokens(x, token_idx, dest, keep, e, cap)
    ones = jnp.ones((t * k,), jnp.float32)
    out = combine_tokens(xg, ones, token_idx, dest, keep, t, sort_idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(k * x),
                               rtol=1e-5, atol=1e-5)
