"""Decode-path correctness: prefill+decode must reproduce full-forward
logits (teacher forcing) for every mixer family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import build_model
from repro.sharding import split_logical

# one arch per mixer family: GQA, GQA+local/global, MLA, RG-LRU hybrid, RWKV
FAMILIES = ["granite-8b", "gemma3-27b", "deepseek-v3-671b",
            "recurrentgemma-9b", "rwkv6-1.6b", "mixtral-8x7b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_then_decode_matches_forward(arch, rng):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    B, S, S_dec = 2, 12, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + S_dec)), jnp.int32)

    # reference: single full forward
    ref_logits, _ = jax.jit(model.forward)(params, {"tokens": toks})

    # prefill first S tokens, then decode one-by-one with teacher forcing
    cache, _ = split_logical(model.init_cache(B, 64))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    logits_p, cache = jax.jit(
        lambda p, b, c, po: model.prefill(p, b, c, positions=po, last_only=False)
    )(params, {"tokens": toks[:, :S]}, cache, pos)

    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(ref_logits[:, :S], np.float32),
        rtol=2e-2, atol=2e-2,
    )

    decode = jax.jit(model.decode_step)
    for t in range(S_dec):
        p = jnp.full((B, 1), S + t, jnp.int32)
        logits_d, cache = decode(params, {"tokens": toks[:, S + t : S + t + 1]},
                                 cache, p)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(ref_logits[:, S + t], np.float32),
            rtol=2e-2, atol=2e-2,
        )


@pytest.mark.parametrize("arch", ["granite-8b", "deepseek-v3-671b",
                                  "recurrentgemma-9b", "rwkv6-1.6b"])
def test_paged_cache_matches_row_cache_bitwise(arch, rng):
    """The paged layout (page pools + block tables for attention layers,
    per-slot state slots for recurrent layers — DESIGN.md §10–11) must
    reproduce the row cache BITWISE across the mixer families: masked
    columns contribute exact softmax zeros and recurrent state slots ARE
    row state, so prefill+decode logits are identical arrays, not merely
    close — that exactness is what lets the serving differential suite
    demand token identity."""
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    B, S, S_dec, max_seq, ps = 2, 6, 3, 16, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + S_dec)),
                       jnp.int32)

    row_cache, _ = split_logical(model.init_cache(B, max_seq))
    paged_cache, paged_axes = split_logical(
        model.init_paged_cache(B, max_seq, ps, num_pages=2 * B * max_seq // ps))
    # identity-ish block tables: slot b owns pages [b*M, (b+1)*M) in logical
    # order — any permutation works, this one is easy to eyeball. Tables are
    # identified by the "page_table" logical axis: recurrent state leaves
    # also carry "batch" and must stay zero-initialized.
    m = max_seq // ps
    tbl = jnp.arange(B * m, dtype=jnp.int32).reshape(B, m)
    paged_cache = jax.tree_util.tree_map(
        lambda leaf, axes: (jnp.broadcast_to(tbl, leaf.shape)
                            if "page_table" in axes else leaf),
        paged_cache, paged_axes, is_leaf=lambda x: hasattr(x, "shape"))

    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    prefill = jax.jit(lambda p, b, c, po: model.prefill(
        p, b, c, positions=po, last_only=False))
    lr, row_cache = prefill(params, {"tokens": toks[:, :S]}, row_cache, pos)
    lp, paged_cache = prefill(params, {"tokens": toks[:, :S]}, paged_cache,
                              pos)
    np.testing.assert_array_equal(np.asarray(lr), np.asarray(lp))

    decode = jax.jit(model.decode_step)
    for t in range(S_dec):
        p = jnp.full((B, 1), S + t, jnp.int32)
        step = {"tokens": toks[:, S + t: S + t + 1]}
        lr, row_cache = decode(params, step, row_cache, p)
        lp, paged_cache = decode(params, step, paged_cache, p)
        np.testing.assert_array_equal(np.asarray(lr), np.asarray(lp))


@pytest.mark.parametrize("arch,n_tables,n_state",
                         [("rwkv6-1.6b", 0, 3),
                          ("recurrentgemma-9b", 1, 2)])
def test_paged_cache_recurrent_state_slots(arch, n_tables, n_state):
    """Recurrent mixers get fixed-size per-slot state slots in the paged
    cache (they used to be rejected): no sequence axis to page, so the
    leaves match the row cache's state rows exactly, while hybrid stacks
    still carry block tables for their attention layers. ``batch`` on a
    state leaf is the slot count."""
    cfg = reduced_config(arch)
    model = build_model(cfg)
    num_slots = 3
    cache, axes = split_logical(model.init_paged_cache(num_slots, 32, 8, 16))
    flat_a = jax.tree_util.tree_leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    flat_v = jax.tree_util.tree_leaves(cache)
    tables = [a for a in flat_a if "page_table" in a]
    state = [(v, a) for v, a in zip(flat_v, flat_a)
             if "batch" in a and "page_table" not in a]
    # scanned segments stack leaves along "layers"; count leaf KINDS
    assert len(tables) == n_tables  # block tables per pattern slot
    assert len(state) >= n_state  # h/conv or wkv/shift_att/shift_ffn
    for v, a in state:
        assert v.shape[a.index("batch")] == num_slots
        assert not v.any()  # fresh state is all-zeros (reset contract)


def test_ring_buffer_windowed_cache(rng):
    """Sliding-window arch decoding past the cache length must match the
    full forward (ring buffer correctness)."""
    cfg = reduced_config("gemma3-27b")  # window 64 locals
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    B, S_total = 1, 40
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_total)), jnp.int32)
    ref_logits, _ = jax.jit(model.forward)(params, {"tokens": toks})

    cache, _ = split_logical(model.init_cache(B, 48))
    decode = jax.jit(model.decode_step)
    for t in range(S_total):
        p = jnp.full((B, 1), t, jnp.int32)
        logits_d, cache = decode(params, {"tokens": toks[:, t : t + 1]}, cache, p)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(ref_logits[:, -1], np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_chunked_attention_matches_unchunked(rng):
    """The flash-style q-chunk path must equal the single-block path."""
    from repro.models import attention as att

    B, S, H, D = 2, 2048, 4, 16  # S multiple of _Q_CHUNK -> chunked path
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, 2, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, 2, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_chunked = att._sdpa(q, k, v, pos, pos, att.GLOBAL_WINDOW)
    # force single-block by monkeypatched chunk size
    old = att._Q_CHUNK
    att._Q_CHUNK = 1 << 30
    try:
        out_full = att._sdpa(q, k, v, pos, pos, att.GLOBAL_WINDOW)
    finally:
        att._Q_CHUNK = old
    np.testing.assert_allclose(np.asarray(out_chunked), np.asarray(out_full),
                               rtol=1e-4, atol=1e-5)
