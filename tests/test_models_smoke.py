"""Per-architecture smoke tests (deliverable f): reduced config, one
forward + one train step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER, get_config, reduced_config
from repro.models import build_model, build_plan
from repro.optim import cosine_warmup_schedule, make_optimizer
from repro.launch.train import make_train_step

ALL_ARCHS = list(ASSIGNED) + list(PAPER)


def _batch_for(cfg, rng, B=2, S=24):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    labs = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.frontend == "vision":
        return {
            "patch_embeddings": jnp.asarray(
                rng.normal(size=(B, cfg.num_prefix_embeddings, cfg.d_model)),
                jnp.float32),
            "tokens": toks,
            "labels": labs,
        }
    if cfg.frontend == "audio":
        return {
            "frame_embeddings": jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S, cfg.num_codebooks)),
                jnp.int32),
        }
    return {"tokens": toks, "labels": labs}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, rng)
    logits, _ = jax.jit(model.forward)(params, batch)
    B = batch["labels"].shape[0]
    S = (batch["tokens"].shape[1] if "tokens" in batch
         else batch["frame_embeddings"].shape[1])
    if cfg.frontend == "vision":
        assert logits.shape[1] == cfg.num_prefix_embeddings + S
    elif cfg.num_codebooks > 1:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    opt = make_optimizer("adamw", cosine_warmup_schedule(1e-3, 5, 100))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    new_params, _, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed (sum of movements across the whole tree —
    # single unused leaves, e.g. audio-stub embed tables, move only by decay)
    delta = sum(
        float(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).sum())
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params))
    )
    assert delta > 1e-3


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_smoke_decode_step(arch, rng):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    B = 2
    from repro.sharding import split_logical

    cache, _ = split_logical(model.init_cache(B, 64))
    if cfg.frontend == "audio":
        db = {"frame_embeddings": jnp.zeros((B, 1, cfg.d_model), jnp.float32)}
    else:
        db = {"tokens": jnp.ones((B, 1), jnp.int32)}
    pos = jnp.zeros((B, 1), jnp.int32)
    logits, nc = jax.jit(model.decode_step)(params, db, cache, pos)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_full_config_structure(arch):
    """FULL configs: plan + analytic param count sanity (no allocation)."""
    cfg = get_config(arch)
    plan = build_plan(cfg)
    assert sum(s.num_layers for s in plan) == cfg.num_layers
    n = cfg.num_params()
    expected = {
        "gemma3-27b": (20e9, 35e9),
        "stablelm-12b": (9e9, 15e9),
        "granite-8b": (6e9, 10e9),
        "llama3-405b": (380e9, 430e9),
        "arctic-480b": (420e9, 520e9),
        "deepseek-v3-671b": (600e9, 720e9),
        "recurrentgemma-9b": (7e9, 12e9),
        "paligemma-3b": (2e9, 4e9),
        "musicgen-medium": (1.2e9, 2.5e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n/1e9:.2f}B params"
    if cfg.is_moe:
        assert cfg.num_active_params() < 0.3 * n


def test_abstract_params_no_allocation():
    cfg = get_config("llama3-405b")
    model = build_model(cfg)
    values, axes = model.abstract_params()
    leaves = jax.tree_util.tree_leaves(values)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    total = sum(int(np.prod(l.shape)) for l in leaves)
    assert 380e9 < total < 430e9
