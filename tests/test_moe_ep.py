"""shard_map expert-parallel MoE: exactness vs the single-device path."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_ep_matches_plain_path():
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.models import build_model
        from repro.models.moe_ep import _EP_MIN_LOCAL_TOKENS
        import repro.models.moe_ep as ep
        ep._EP_MIN_LOCAL_TOKENS = 1  # force EP on the tiny test batch
        from repro.launch.mesh import make_mesh
        from repro.sharding import make_rules, use_rules, shardings_from_axes

        cfg = reduced_config("deepseek-v3-671b")
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, num_experts=8,
                                         capacity_factor=4.0))
        model = build_model(cfg)
        params, axes = model.init_split(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
        ref, _ = jax.jit(model.forward)(params, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        abs_p, _ = model.abstract_params()
        sh = shardings_from_axes(axes, rules, abs_p)

        def fwd(p, b):
            with use_rules(rules):
                return model.forward(p, b)[0]

        with mesh:
            p = jax.device_put(params, sh)
            got = jax.jit(fwd)(p, batch)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        assert err < 3e-2, err
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_ep_gating():
    """EP must not engage for tiny token counts or indivisible experts."""
    import dataclasses

    from repro.configs import reduced_config
    from repro.models.moe_ep import ep_applicable

    cfg = reduced_config("deepseek-v3-671b")
    assert not ep_applicable({"w1": None}, cfg, None)  # no rules context
