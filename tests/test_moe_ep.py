"""shard_map expert-parallel MoE: exactness vs the single-device path."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_ep_matches_plain_path():
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.models import build_model
        from repro.models.moe_ep import _EP_MIN_LOCAL_TOKENS
        import repro.models.moe_ep as ep
        ep._EP_MIN_LOCAL_TOKENS = 1  # force EP on the tiny test batch
        from repro.launch.mesh import make_mesh
        from repro.sharding import make_rules, use_rules, shardings_from_axes

        cfg = reduced_config("deepseek-v3-671b")
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, num_experts=8,
                                         capacity_factor=4.0))
        model = build_model(cfg)
        params, axes = model.init_split(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
        ref, _ = jax.jit(model.forward)(params, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        abs_p, _ = model.abstract_params()
        sh = shardings_from_axes(axes, rules, abs_p)

        def fwd(p, b):
            with use_rules(rules):
                return model.forward(p, b)[0]

        with mesh:
            p = jax.device_put(params, sh)
            got = jax.jit(fwd)(p, batch)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        assert err < 3e-2, err
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_ep_gating():
    """EP must not engage for tiny token counts or indivisible experts."""
    import dataclasses

    from repro.configs import reduced_config
    from repro.models.moe_ep import ep_applicable

    cfg = reduced_config("deepseek-v3-671b")
    assert not ep_applicable({"w1": None}, cfg, None)  # no rules context
    # compressed stores are gated the same way without a rules context
    svd_store = {"center": {}, "u": None, "v": {}}
    assert not ep_applicable(svd_store, cfg, None, apply_mode="fused")


def test_ep_compressed_matches_gspmd_fused():
    """ResMoE-SVD store under EP == the GSPMD fused path (GLU config), for
    both the einsum `fused` and the grouped-Pallas `fused_kernel` modes,
    with exactly ONE [T_loc, d] psum per MoE layer in the lowered HLO."""
    code = textwrap.dedent("""
        import dataclasses, re
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.models import build_model, compress_model_params
        from repro.models.model import abstract_compressed_params
        from repro.models.moe import moe_layer
        from repro.models.moe_ep import ep_applicable
        from repro.launch.mesh import make_mesh
        from repro.sharding import make_rules, use_rules, shardings_from_axes

        cfg = reduced_config("mixtral-8x7b")
        cfg = dataclasses.replace(
            cfg,
            moe=dataclasses.replace(cfg.moe, ep_min_local_tokens=1),
            resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                       keep_ratio=0.5))
        model = build_model(cfg)
        params, _ = model.init_split(jax.random.PRNGKey(0))
        cp, _ = compress_model_params(params, cfg)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
        ref, _ = jax.jit(
            lambda p, b: model.forward(p, b, apply_mode="fused"))(cp, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)

        # gating: restore-free modes only; delta stores stay GSPMD
        store_keys = {"center": {}, "u": None, "v": {}}
        assert ep_applicable(store_keys, cfg, rules, num_tokens=10_000,
                             apply_mode="fused")
        assert ep_applicable(store_keys, cfg, rules, num_tokens=10_000,
                             apply_mode="fused_kernel")
        assert not ep_applicable(store_keys, cfg, rules, num_tokens=10_000,
                                 apply_mode="restored")
        assert not ep_applicable(store_keys, cfg, rules, num_tokens=10_000,
                                 apply_mode="fused_shared")
        assert not ep_applicable({"center": {}, "delta": {}}, cfg, rules,
                                 num_tokens=10_000, apply_mode="fused")
        # tokens not divisible by |data| (odd B=1 prefill) must decline EP
        # instead of crashing shard_map's P(batch, None) in_spec
        assert not ep_applicable(store_keys, cfg, rules, num_tokens=4097,
                                 apply_mode="fused")

        abs_v, axes = abstract_compressed_params(cfg)
        sh = shardings_from_axes(axes, rules, abs_v)
        for mode in ("fused", "fused_kernel"):
            def fwd(p, b, m=mode):
                with use_rules(rules):
                    return model.forward(p, b, apply_mode=m)[0]
            with mesh:
                p = jax.device_put(cp, sh)
                got = jax.jit(fwd)(p, batch)
            err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                        - ref.astype(jnp.float32))))
            assert err < 1e-3, (mode, err)

        # one [T_loc, d] psum per layer: lower ONE MoE layer and count
        # >=2-d all-reduces (aux pmeans are scalar)
        ffn = cp["segments"][0]["slots"][0]["ffn"]
        bank = jax.tree_util.tree_map(lambda a: jnp.asarray(a[0]), ffn)
        x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)), jnp.float32)
        def layer(p, xx):
            with use_rules(rules):
                return moe_layer(p, xx, cfg, apply_mode="fused")[0]
        with mesh:
            text = jax.jit(layer).lower(bank, x).compile().as_text()
        # anchor on the instruction (`= f32[..] all-reduce(`): bitcasts OF
        # the all-reduce result would otherwise double-count
        big_ars = re.findall(
            r"= *f32\\[(\\d+),(\\d+)\\]\\S* all-reduce\\(", text)
        assert len(big_ars) == 1, big_ars
        t_loc = 2 * 32 // 2  # T / |data|
        assert big_ars[0] == (str(t_loc), str(cfg.d_model)), big_ars
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_ep_compressed_nonglu():
    """Non-GLU (relu, top-1) compressed store under EP == GSPMD fused."""
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.models import build_model, compress_model_params
        from repro.models.model import abstract_compressed_params
        from repro.launch.mesh import make_mesh
        from repro.sharding import make_rules, use_rules, shardings_from_axes

        cfg = reduced_config("switch-base-8")
        assert not cfg.glu
        # capacity_factor high enough that the per-shard LOCAL capacity
        # (computed from t_loc) never drops pairs the global path keeps
        cfg = dataclasses.replace(
            cfg,
            moe=dataclasses.replace(cfg.moe, ep_min_local_tokens=1,
                                    capacity_factor=8.0),
            resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                       keep_ratio=0.5, apply_mode="fused"))
        model = build_model(cfg)
        params, _ = model.init_split(jax.random.PRNGKey(0))
        cp, _ = compress_model_params(params, cfg)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
        ref, _ = jax.jit(
            lambda p, b: model.forward(p, b, apply_mode="fused"))(cp, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        abs_v, axes = abstract_compressed_params(cfg)
        sh = shardings_from_axes(axes, rules, abs_v)
        for mode in ("fused", "fused_kernel"):
            def fwd(p, b, m=mode):
                with use_rules(rules):
                    return model.forward(p, b, apply_mode=m)[0]
            with mesh:
                p = jax.device_put(cp, sh)
                got = jax.jit(fwd)(p, batch)
            err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                        - ref.astype(jnp.float32))))
            assert err < 1e-3, (mode, err)
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_server_compressed_on_mesh():
    """Server(rules=...) serves a compressed model on a multi-device mesh
    and reproduces the single-device compressed generation."""
    code = textwrap.dedent("""
        import dataclasses
        import jax, numpy as np
        from repro.configs import reduced_config
        from repro.launch.mesh import make_mesh
        from repro.launch.serve import Request, Server
        from repro.models import build_model, compress_model_params
        from repro.models.model import abstract_compressed_params
        from repro.sharding import make_rules

        cfg = reduced_config("mixtral-8x7b")
        cfg = dataclasses.replace(
            cfg,
            moe=dataclasses.replace(cfg.moe, ep_min_local_tokens=1),
            resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                       keep_ratio=0.5))
        model = build_model(cfg)
        params, _ = model.init_split(jax.random.PRNGKey(0))
        cp, _ = compress_model_params(params, cfg)
        _, axes = abstract_compressed_params(cfg)
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)

        single = Server(model, cp, num_slots=2, max_seq=64,
                        apply_mode="fused")
        r1 = Request(prompt=prompt, max_new_tokens=5)
        single.serve([r1])

        rules = make_rules(make_mesh((2, 4), ("data", "model")))
        sharded = Server(model, cp, num_slots=2, max_seq=64,
                         apply_mode="fused", rules=rules, param_axes=axes)
        r2 = Request(prompt=prompt, max_new_tokens=5)
        sharded.serve([r2])
        assert r1.output == r2.output, (r1.output, r2.output)
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
