"""Sharding rules + multi-device correctness (subprocess with 8 CPU devices)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# -- pure rule logic (no devices needed) -------------------------------------


def test_spec_divisibility_fallback():
    import jax
    from repro.launch.mesh import make_mesh
    from repro.sharding import make_rules

    # single CPU device: 1x1 mesh still exercises the rule logic
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh)
    spec = rules.spec_for(("experts", "embed", "expert_mlp"), (8, 16, 32))
    assert spec == jax.sharding.PartitionSpec("model", "data", None)


def test_spec_dedup_and_nondivisible():
    from repro.launch.mesh import make_mesh
    from repro.sharding import make_rules

    mesh = make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh)
    # duplicate logical axis: second 'embed' must drop to None
    spec = rules.spec_for(("embed", "embed"), (16, 16))
    assert spec[0] == "data" and spec[1] is None


def test_spec_shape_aware_drop():
    code = textwrap.dedent("""
        import jax
        from repro.launch.mesh import make_mesh
        from repro.sharding import make_rules
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        # heads=54 not divisible by model=4 -> replicated
        s1 = rules.spec_for(("embed", "heads", None), (8, 54, 16))
        assert s1[1] is None, s1
        s2 = rules.spec_for(("embed", "heads", None), (8, 8, 16))
        assert s2[1] == "model", s2
        print("OK")
    """)
    assert "OK" in run_with_devices(code, 8)


# -- multi-device numerics -----------------------------------------------------


def test_pjit_train_step_matches_single_device():
    """One train step on a 2x4 mesh == single-device step (same math)."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.models import build_model
        from repro.optim import make_optimizer, cosine_warmup_schedule
        from repro.launch.train import make_train_step, jit_train_step
        from repro.launch.mesh import make_mesh
        from repro.data import make_pipeline

        cfg = reduced_config("mixtral-8x7b")
        model = build_model(cfg)
        params, _ = model.init_split(jax.random.PRNGKey(0))
        opt = make_optimizer("adamw", cosine_warmup_schedule(1e-3, 2, 100))
        opt_state = opt.init(params)
        pipe = make_pipeline(cfg, 32, 8)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}

        # single-device reference
        ref_step = jax.jit(make_train_step(model, opt))
        ref_params, _, ref_metrics = ref_step(params, opt_state, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        step, psh, osh, bsh = jit_train_step(model, opt, mesh, donate=False)
        with mesh:
            p = jax.device_put(params, psh)
            o = jax.device_put(opt_state, osh)
            b = jax.device_put(batch, bsh(batch))
            new_params, _, metrics = step(p, o, b)
        np.testing.assert_allclose(float(metrics["loss"]),
                                   float(ref_metrics["loss"]), rtol=2e-4)
        for a, c in zip(jax.tree_util.tree_leaves(ref_params),
                        jax.tree_util.tree_leaves(new_params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(c, np.float32),
                                       rtol=3e-3, atol=3e-4)
        print("OK")
    """)
    assert "OK" in run_with_devices(code, 8)


def test_grad_compression_close_to_exact():
    """int8 error-feedback DP all-reduce: one step close to exact; error
    buffers carry the quantization residual."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.models import build_model
        from repro.optim import make_optimizer, cosine_warmup_schedule
        from repro.optim.compression import init_grad_compression
        from repro.launch.train import make_dp_compressed_train_step, make_train_step
        from repro.launch.mesh import make_mesh
        from repro.data import make_pipeline

        cfg = reduced_config("granite-8b")
        model = build_model(cfg)
        params, _ = model.init_split(jax.random.PRNGKey(0))
        opt = make_optimizer("adamw", cosine_warmup_schedule(1e-3, 2, 100))
        opt_state = opt.init(params)
        comp = init_grad_compression(params)
        pipe = make_pipeline(cfg, 16, 8)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}

        mesh = make_mesh((8,), ("data",))
        with mesh:
            step = jax.jit(make_dp_compressed_train_step(model, opt, mesh))
            new_p, _, new_comp, metrics = step(params, opt_state, comp, batch)
        ref_step = jax.jit(make_train_step(model, opt))
        ref_p, _, ref_metrics = ref_step(params, opt_state, batch)
        np.testing.assert_allclose(float(metrics["loss"]),
                                   float(ref_metrics["loss"]), rtol=1e-3)
        # compressed params close to exact-step params
        num = den = 0.0
        for a, b in zip(jax.tree_util.tree_leaves(ref_p),
                        jax.tree_util.tree_leaves(new_p)):
            num += float(((np.asarray(a, np.float32) -
                           np.asarray(b, np.float32)) ** 2).sum())
            den += float((np.asarray(a, np.float32) ** 2).sum())
        assert num / den < 1e-3, num / den
        err_norm = sum(float((np.asarray(e) ** 2).sum())
                       for e in jax.tree_util.tree_leaves(new_comp.error))
        assert err_norm > 0  # feedback is live
        print("OK")
    """)
    assert "OK" in run_with_devices(code, 8)


def test_elastic_reshard_roundtrip():
    """Checkpoint written on a (4,2) mesh restores onto (2,4) and (8,) —
    the elastic-rescale path."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.configs import reduced_config
        from repro.models import build_model
        from repro.launch.mesh import make_mesh
        from repro.sharding import make_rules, shardings_from_axes
        from repro.checkpoint import Checkpointer, reshard

        cfg = reduced_config("granite-8b")
        model = build_model(cfg)
        params, axes = model.init_split(jax.random.PRNGKey(0))
        abs_p, _ = model.abstract_params()

        mesh_a = make_mesh((4, 2), ("data", "model"))
        sh_a = shardings_from_axes(axes, make_rules(mesh_a), abs_p)
        pa = jax.device_put(params, sh_a)
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        ck.save(1, pa)

        for shape, names in [((2, 4), ("data", "model")), ((8,), ("data",))]:
            mesh_b = make_mesh(shape, names)
            sh_b = shardings_from_axes(axes, make_rules(mesh_b), abs_p)
            pb, _ = ck.restore(1, params, shardings=sh_b)
            for x, y in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(pb)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        # live reshard too
        pc = reshard(pa, sh_b)
        for x, y in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(pc)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("OK")
    """)
    assert "OK" in run_with_devices(code, 8)
