"""Serving loop: continuous batching equals sequential greedy decoding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.serve import Request, Server
from repro.models import build_model, compress_model_params
from repro.sharding import split_logical


def _sequential_generate(model, params, prompt, max_new):
    """Reference: naive prefill + decode for a single prompt."""
    cache, _ = split_logical(model.init_cache(1, 128))
    s = len(prompt)
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                                  cache, positions=pos)
    out = [int(jnp.argmax(logits[0, -1]))]
    for t in range(max_new - 1):
        p = jnp.full((1, 1), s + t, jnp.int32)
        logits, cache = model.decode_step(
            params, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)}, cache, p)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def test_server_matches_sequential(rng):
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    prompts = [rng.integers(0, cfg.vocab_size, size=(rng.integers(4, 10),))
               .astype(np.int32) for _ in range(5)]
    server = Server(model, params, num_slots=3, max_seq=128)
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    server.serve(reqs)
    for p, r in zip(prompts, reqs):
        ref = _sequential_generate(model, params, p, 6)
        assert r.output == ref, (r.output, ref)


def test_server_with_compressed_params(rng):
    """Serving with ResMoE-compressed params: runs; near-lossless store
    reproduces the dense generation."""
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="up", keep_ratio=1.0,
                                        apply_mode="restored"))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)

    dense = Server(model, params, num_slots=2, max_seq=64)
    comp = Server(model, cp, num_slots=2, max_seq=64, apply_mode="restored")
    r1 = Request(prompt=prompt, max_new_tokens=5)
    r2 = Request(prompt=prompt, max_new_tokens=5)
    dense.serve([r1])
    comp.serve([r2])
    assert r1.output == r2.output
