"""Serving loop: continuous batching equals sequential greedy decoding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.serve import Request, Server
from repro.models import build_model, compress_model_params
from repro.sharding import split_logical


def _sequential_generate(model, params, prompt, max_new):
    """Reference: naive prefill + decode for a single prompt."""
    cache, _ = split_logical(model.init_cache(1, 128))
    s = len(prompt)
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                                  cache, positions=pos)
    out = [int(jnp.argmax(logits[0, -1]))]
    for t in range(max_new - 1):
        p = jnp.full((1, 1), s + t, jnp.int32)
        logits, cache = model.decode_step(
            params, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)}, cache, p)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def test_server_matches_sequential(rng):
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    prompts = [rng.integers(0, cfg.vocab_size, size=(rng.integers(4, 10),))
               .astype(np.int32) for _ in range(5)]
    server = Server(model, params, num_slots=3, max_seq=128)
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    server.serve(reqs)
    for p, r in zip(prompts, reqs):
        ref = _sequential_generate(model, params, p, 6)
        assert r.output == ref, (r.output, ref)


def test_server_respects_max_new_tokens(rng):
    """No decode overshoot: a request never receives more than
    max_new_tokens tokens (a max_new_tokens=1 request used to get 2)."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    server = Server(model, params, num_slots=2, max_seq=64)
    prompts = [rng.integers(0, cfg.vocab_size, size=(5,)).astype(np.int32)
               for _ in range(4)]
    reqs = [Request(prompt=p, max_new_tokens=n)
            for p, n in zip(prompts, (1, 1, 2, 4))]
    server.serve(reqs)
    for r in reqs:
        assert len(r.output) == r.max_new_tokens, (len(r.output),
                                                   r.max_new_tokens)
    # the emitted prefixes must agree with an unconstrained generation
    ref = _sequential_generate(model, params, prompts[0], 4)
    assert reqs[0].output == ref[:1]


def test_server_honors_eos(rng):
    """Generation stops AT the first EOS token (still emitted, never
    continued past) — including an EOS produced by prefill itself."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
    free = _sequential_generate(model, params, prompt, 8)
    # pick the greedy continuation's 3rd token as "EOS" so it fires mid-decode
    eos = free[2]
    server = Server(model, params, num_slots=2, max_seq=64)
    req = Request(prompt=prompt, max_new_tokens=8, eos_id=eos)
    server.serve([req])
    first = req.output.index(eos)
    assert req.output == free[: first + 1]
    assert len(req.output) <= req.max_new_tokens
    # EOS at the very first (prefill-emitted) token
    server2 = Server(model, params, num_slots=2, max_seq=64)
    req2 = Request(prompt=prompt, max_new_tokens=8, eos_id=free[0])
    server2.serve([req2])
    assert req2.output == [free[0]]


def test_server_fused_token_generation_parity(rng):
    """Serving an SVD store with apply_mode='fused_token' (ragged per-token
    decode path, no dispatch buffer) reproduces the dispatched fused
    generation token-for-token."""
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg,
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                token_path_max_tokens=0),
        resmoe=dataclasses.replace(cfg.resmoe, method="svd", keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(3)]

    dispatched = Server(model, cp, num_slots=2, max_seq=64, apply_mode="fused")
    token = Server(model, cp, num_slots=2, max_seq=64,
                   apply_mode="fused_token")
    reqs_a = [Request(prompt=p, max_new_tokens=5) for p in prompts]
    reqs_b = [Request(prompt=p, max_new_tokens=5) for p in prompts]
    dispatched.serve(reqs_a)
    token.serve(reqs_b)
    for a, b in zip(reqs_a, reqs_b):
        assert a.output == b.output, (a.output, b.output)


def test_server_rejects_oversized_prompt(rng):
    """A prompt longer than the cache row is rejected with a clear error
    (it used to be accepted and silently overrun the B=1 prefill row with
    clamped writes) — BEFORE any request of the batch is admitted, so the
    Server is left clean and serviceable."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    server = Server(model, params, num_slots=2, max_seq=16)
    ok = rng.integers(0, cfg.vocab_size, size=(5,)).astype(np.int32)
    long_prompt = rng.integers(0, cfg.vocab_size, size=(16,)).astype(np.int32)
    with pytest.raises(ValueError, match="max_seq"):
        server.serve([Request(prompt=ok, max_new_tokens=2),
                      Request(prompt=long_prompt, max_new_tokens=2)])
    assert all(server.slot_free)  # nothing half-admitted
    req = Request(prompt=ok, max_new_tokens=2)
    server.serve([req])  # the same Server still serves cleanly
    assert len(req.output) == 2


def test_server_truncate_prompts_flag(rng):
    """With truncate_prompts=True an oversized prompt is LEFT-truncated to
    the most recent max_seq-1 tokens and generates exactly like the
    pre-truncated prompt."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    max_seq = 16
    long_prompt = rng.integers(0, cfg.vocab_size, size=(20,)).astype(np.int32)
    kept = long_prompt[-(max_seq - 1):]

    trunc = Server(model, params, num_slots=2, max_seq=max_seq,
                   truncate_prompts=True)
    r1 = Request(prompt=long_prompt, max_new_tokens=2)
    trunc.serve([r1])
    ref = Server(model, params, num_slots=2, max_seq=max_seq)
    r2 = Request(prompt=kept, max_new_tokens=2)
    ref.serve([r2])
    assert r1.output == r2.output


def test_server_uses_last_cache_position(rng):
    """Boundary at max_seq: the stop condition must fire only when the
    NEXT write would overrun, so a sequence can fill every cache position.
    The old `>= max_seq - 1` check left the last writable position unused
    and truncated one token early."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    max_seq = 16
    s = 12
    prompt = rng.integers(0, cfg.vocab_size, size=(s,)).astype(np.int32)
    server = Server(model, params, num_slots=2, max_seq=max_seq)
    req = Request(prompt=prompt, max_new_tokens=100)  # cache-bound
    server.serve([req])
    # prefill emits 1 token; decode then writes positions s..max_seq-1 —
    # exactly max_seq - s more tokens
    assert len(req.output) == max_seq - s + 1, len(req.output)
    # and the emitted tokens agree with an unconstrained reference
    ref = _sequential_generate(model, params, prompt, max_seq - s + 1)
    assert req.output == ref


def test_server_with_compressed_params(rng):
    """Serving with ResMoE-compressed params: runs; near-lossless store
    reproduces the dense generation."""
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="up", keep_ratio=1.0,
                                        apply_mode="restored"))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)

    dense = Server(model, params, num_slots=2, max_seq=64)
    comp = Server(model, cp, num_slots=2, max_seq=64, apply_mode="restored")
    r1 = Request(prompt=prompt, max_new_tokens=5)
    r2 = Request(prompt=prompt, max_new_tokens=5)
    dense.serve([r1])
    comp.serve([r2])
    assert r1.output == r2.output
