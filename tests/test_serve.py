"""Serving loop: continuous batching equals sequential greedy decoding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.serve import Request, Server
from repro.models import build_model, compress_model_params
from repro.sharding import split_logical


def _sequential_generate(model, params, prompt, max_new):
    """Reference: naive prefill + decode for a single prompt."""
    cache, _ = split_logical(model.init_cache(1, 128))
    s = len(prompt)
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                                  cache, positions=pos)
    out = [int(jnp.argmax(logits[0, -1]))]
    for t in range(max_new - 1):
        p = jnp.full((1, 1), s + t, jnp.int32)
        logits, cache = model.decode_step(
            params, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)}, cache, p)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def test_server_matches_sequential(rng):
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    prompts = [rng.integers(0, cfg.vocab_size, size=(rng.integers(4, 10),))
               .astype(np.int32) for _ in range(5)]
    server = Server(model, params, num_slots=3, max_seq=128)
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    server.serve(reqs)
    for p, r in zip(prompts, reqs):
        ref = _sequential_generate(model, params, p, 6)
        assert r.output == ref, (r.output, ref)


def test_server_respects_max_new_tokens(rng):
    """No decode overshoot: a request never receives more than
    max_new_tokens tokens (a max_new_tokens=1 request used to get 2)."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    server = Server(model, params, num_slots=2, max_seq=64)
    prompts = [rng.integers(0, cfg.vocab_size, size=(5,)).astype(np.int32)
               for _ in range(4)]
    reqs = [Request(prompt=p, max_new_tokens=n)
            for p, n in zip(prompts, (1, 1, 2, 4))]
    server.serve(reqs)
    for r in reqs:
        assert len(r.output) == r.max_new_tokens, (len(r.output),
                                                   r.max_new_tokens)
    # the emitted prefixes must agree with an unconstrained generation
    ref = _sequential_generate(model, params, prompts[0], 4)
    assert reqs[0].output == ref[:1]


def test_server_honors_eos(rng):
    """Generation stops AT the first EOS token (still emitted, never
    continued past) — including an EOS produced by prefill itself."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
    free = _sequential_generate(model, params, prompt, 8)
    # pick the greedy continuation's 3rd token as "EOS" so it fires mid-decode
    eos = free[2]
    server = Server(model, params, num_slots=2, max_seq=64)
    req = Request(prompt=prompt, max_new_tokens=8, eos_id=eos)
    server.serve([req])
    first = req.output.index(eos)
    assert req.output == free[: first + 1]
    assert len(req.output) <= req.max_new_tokens
    # EOS at the very first (prefill-emitted) token
    server2 = Server(model, params, num_slots=2, max_seq=64)
    req2 = Request(prompt=prompt, max_new_tokens=8, eos_id=free[0])
    server2.serve([req2])
    assert req2.output == [free[0]]


def test_server_fused_token_generation_parity(rng):
    """Serving an SVD store with apply_mode='fused_token' (ragged per-token
    decode path, no dispatch buffer) reproduces the dispatched fused
    generation token-for-token."""
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg,
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                token_path_max_tokens=0),
        resmoe=dataclasses.replace(cfg.resmoe, method="svd", keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(3)]

    dispatched = Server(model, cp, num_slots=2, max_seq=64, apply_mode="fused")
    token = Server(model, cp, num_slots=2, max_seq=64,
                   apply_mode="fused_token")
    reqs_a = [Request(prompt=p, max_new_tokens=5) for p in prompts]
    reqs_b = [Request(prompt=p, max_new_tokens=5) for p in prompts]
    dispatched.serve(reqs_a)
    token.serve(reqs_b)
    for a, b in zip(reqs_a, reqs_b):
        assert a.output == b.output, (a.output, b.output)


def test_server_with_compressed_params(rng):
    """Serving with ResMoE-compressed params: runs; near-lossless store
    reproduces the dense generation."""
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="up", keep_ratio=1.0,
                                        apply_mode="restored"))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)

    dense = Server(model, params, num_slots=2, max_seq=64)
    comp = Server(model, cp, num_slots=2, max_seq=64, apply_mode="restored")
    r1 = Request(prompt=prompt, max_new_tokens=5)
    r2 = Request(prompt=prompt, max_new_tokens=5)
    dense.serve([r1])
    comp.serve([r2])
    assert r1.output == r2.output
