"""Serving loop: continuous batching equals sequential greedy decoding.

Two servers live in launch/serve.py: the slot-synchronous ``Server`` (one
full cache row per slot) and the paged ``ContinuousServer`` (shared page
pool, per-step join/leave, preemption). The differential suite at the
bottom pins the latter to the former token-for-token across randomized
schedules — the sync server is the oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import MoEConfig
from repro.launch.serve import ContinuousServer, Request, Server
from repro.models import (
    build_model,
    compress_model_params,
    quantize_compressed_params,
)
from repro.sharding import split_logical


def _sequential_generate(model, params, prompt, max_new):
    """Reference: naive prefill + decode for a single prompt."""
    cache, _ = split_logical(model.init_cache(1, 128))
    s = len(prompt)
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                                  cache, positions=pos)
    out = [int(jnp.argmax(logits[0, -1]))]
    for t in range(max_new - 1):
        p = jnp.full((1, 1), s + t, jnp.int32)
        logits, cache = model.decode_step(
            params, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)}, cache, p)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def test_server_matches_sequential(rng):
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    prompts = [rng.integers(0, cfg.vocab_size, size=(rng.integers(4, 10),))
               .astype(np.int32) for _ in range(5)]
    server = Server(model, params, num_slots=3, max_seq=128)
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    server.serve(reqs)
    for p, r in zip(prompts, reqs):
        ref = _sequential_generate(model, params, p, 6)
        assert r.output == ref, (r.output, ref)


def test_server_respects_max_new_tokens(rng):
    """No decode overshoot: a request never receives more than
    max_new_tokens tokens (a max_new_tokens=1 request used to get 2)."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    server = Server(model, params, num_slots=2, max_seq=64)
    prompts = [rng.integers(0, cfg.vocab_size, size=(5,)).astype(np.int32)
               for _ in range(4)]
    reqs = [Request(prompt=p, max_new_tokens=n)
            for p, n in zip(prompts, (1, 1, 2, 4))]
    server.serve(reqs)
    for r in reqs:
        assert len(r.output) == r.max_new_tokens, (len(r.output),
                                                   r.max_new_tokens)
    # the emitted prefixes must agree with an unconstrained generation
    ref = _sequential_generate(model, params, prompts[0], 4)
    assert reqs[0].output == ref[:1]


def test_server_honors_eos(rng):
    """Generation stops AT the first EOS token (still emitted, never
    continued past) — including an EOS produced by prefill itself."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
    free = _sequential_generate(model, params, prompt, 8)
    # pick the greedy continuation's 3rd token as "EOS" so it fires mid-decode
    eos = free[2]
    server = Server(model, params, num_slots=2, max_seq=64)
    req = Request(prompt=prompt, max_new_tokens=8, eos_id=eos)
    server.serve([req])
    first = req.output.index(eos)
    assert req.output == free[: first + 1]
    assert len(req.output) <= req.max_new_tokens
    # EOS at the very first (prefill-emitted) token
    server2 = Server(model, params, num_slots=2, max_seq=64)
    req2 = Request(prompt=prompt, max_new_tokens=8, eos_id=free[0])
    server2.serve([req2])
    assert req2.output == [free[0]]


def test_server_fused_token_generation_parity(rng):
    """Serving an SVD store with apply_mode='fused_token' (ragged per-token
    decode path, no dispatch buffer) reproduces the dispatched fused
    generation token-for-token."""
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg,
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                token_path_max_tokens=0),
        resmoe=dataclasses.replace(cfg.resmoe, method="svd", keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(3)]

    dispatched = Server(model, cp, num_slots=2, max_seq=64, apply_mode="fused")
    token = Server(model, cp, num_slots=2, max_seq=64,
                   apply_mode="fused_token")
    reqs_a = [Request(prompt=p, max_new_tokens=5) for p in prompts]
    reqs_b = [Request(prompt=p, max_new_tokens=5) for p in prompts]
    dispatched.serve(reqs_a)
    token.serve(reqs_b)
    for a, b in zip(reqs_a, reqs_b):
        assert a.output == b.output, (a.output, b.output)


def test_server_rejects_oversized_prompt(rng):
    """A prompt longer than the cache row is rejected with a clear error
    (it used to be accepted and silently overrun the B=1 prefill row with
    clamped writes) — BEFORE any request of the batch is admitted, so the
    Server is left clean and serviceable."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    server = Server(model, params, num_slots=2, max_seq=16)
    ok = rng.integers(0, cfg.vocab_size, size=(5,)).astype(np.int32)
    long_prompt = rng.integers(0, cfg.vocab_size, size=(16,)).astype(np.int32)
    with pytest.raises(ValueError, match="max_seq"):
        server.serve([Request(prompt=ok, max_new_tokens=2),
                      Request(prompt=long_prompt, max_new_tokens=2)])
    assert all(server.slot_free)  # nothing half-admitted
    req = Request(prompt=ok, max_new_tokens=2)
    server.serve([req])  # the same Server still serves cleanly
    assert len(req.output) == 2


def test_server_truncate_prompts_flag(rng):
    """With truncate_prompts=True an oversized prompt is LEFT-truncated to
    the most recent max_seq-1 tokens and generates exactly like the
    pre-truncated prompt."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    max_seq = 16
    long_prompt = rng.integers(0, cfg.vocab_size, size=(20,)).astype(np.int32)
    kept = long_prompt[-(max_seq - 1):]

    trunc = Server(model, params, num_slots=2, max_seq=max_seq,
                   truncate_prompts=True)
    r1 = Request(prompt=long_prompt, max_new_tokens=2)
    trunc.serve([r1])
    ref = Server(model, params, num_slots=2, max_seq=max_seq)
    r2 = Request(prompt=kept, max_new_tokens=2)
    ref.serve([r2])
    assert r1.output == r2.output


def test_server_uses_last_cache_position(rng):
    """Boundary at max_seq: the stop condition must fire only when the
    NEXT write would overrun, so a sequence can fill every cache position.
    The old `>= max_seq - 1` check left the last writable position unused
    and truncated one token early."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    max_seq = 16
    s = 12
    prompt = rng.integers(0, cfg.vocab_size, size=(s,)).astype(np.int32)
    server = Server(model, params, num_slots=2, max_seq=max_seq)
    req = Request(prompt=prompt, max_new_tokens=100)  # cache-bound
    server.serve([req])
    # prefill emits 1 token; decode then writes positions s..max_seq-1 —
    # exactly max_seq - s more tokens
    assert len(req.output) == max_seq - s + 1, len(req.output)
    # and the emitted tokens agree with an unconstrained reference
    ref = _sequential_generate(model, params, prompt, max_seq - s + 1)
    assert req.output == ref


def test_server_with_compressed_params(rng):
    """Serving with ResMoE-compressed params: runs; near-lossless store
    reproduces the dense generation."""
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="up", keep_ratio=1.0,
                                        apply_mode="restored"))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)

    dense = Server(model, params, num_slots=2, max_seq=64)
    comp = Server(model, cp, num_slots=2, max_seq=64, apply_mode="restored")
    r1 = Request(prompt=prompt, max_new_tokens=5)
    r2 = Request(prompt=prompt, max_new_tokens=5)
    dense.serve([r1])
    comp.serve([r2])
    assert r1.output == r2.output


# ---------------------------------------------------------------------------
# ContinuousServer (paged KV + preemption) differential suite
# ---------------------------------------------------------------------------


def _random_schedule(seed, vocab, n_lo=2, n_hi=5, max_new_hi=7):
    """One randomized serving schedule: prompts, budgets, arrival trace.

    Prompt lengths draw from a small set so the B=1 prefill only ever
    traces a handful of shapes across the whole suite.
    """
    r = np.random.default_rng(seed)
    n = int(r.integers(n_lo, n_hi + 1))
    prompts = [r.integers(0, vocab, size=(int(r.choice([4, 6, 8])),))
               .astype(np.int32) for _ in range(n)]
    max_new = [int(r.integers(1, max_new_hi)) for _ in range(n)]
    order = r.permutation(n)
    arrivals = np.sort(r.poisson(1.0, size=n)).tolist()
    return prompts, max_new, order, arrivals


def _assert_differential(model, params, schedules, apply_mode=None,
                         num_slots=3, max_seq=48, page_size=4, pool_pages=9,
                         max_new_override=None, preempt_steps=None,
                         spec_k=0):
    """Serve each schedule through both servers; outputs must be identical.

    The ContinuousServer sees the requests in a permuted order under a
    Poisson arrival trace — scheduling must never change greedy outputs.
    ``preempt_steps`` forces an eviction at given step indices (each fires
    once) so architectures whose state never runs out of pages — pure
    recurrence holds one fixed slot per sequence — still exercise the
    preempt/recompute-restore path. ``spec_k`` turns on barycenter-draft
    speculative decoding on the ContinuousServer ONLY — the sync Server
    stays the plain-decode oracle, so passing spec_k > 0 asserts spec is
    a pure latency knob (token-identical outputs, DESIGN.md §12).
    Returns the total preemption count so callers can assert the
    interesting regime was exercised.
    """
    cfg = model.cfg
    sync = Server(model, params, num_slots=num_slots, max_seq=max_seq,
                  apply_mode=apply_mode)
    cont = ContinuousServer(model, params, num_slots=num_slots,
                            max_seq=max_seq, page_size=page_size,
                            pool_pages=pool_pages, apply_mode=apply_mode,
                            preempt_steps=preempt_steps, spec_k=spec_k)
    for seed in schedules:
        prompts, max_new, order, arrivals = _random_schedule(
            seed, cfg.vocab_size)
        if max_new_override is not None:
            max_new = [max_new_override] * len(max_new)
        ra = [Request(prompt=p, max_new_tokens=m)
              for p, m in zip(prompts, max_new)]
        rb = [Request(prompt=p, max_new_tokens=m)
              for p, m in zip(prompts, max_new)]
        sync.serve(ra)
        cont.serve([rb[i] for i in order], arrival_steps=arrivals)
        for i, (a, b) in enumerate(zip(ra, rb)):
            assert a.output == b.output, (seed, i, a.output, b.output)
        # the pool must come back empty after every schedule: leaked pages
        # would starve later schedules (and falsify the utilization stats).
        # Pure-recurrent stacks have no pool — ServingState.check() still
        # validates their slot occupancy.
        if cont.pool is not None:
            cont.pool.check()
            assert cont.pool.pages_in_use == 0
        cont.state.check()
    return cont.stats["preemptions"]


def test_continuous_server_differential_dense(rng):
    """20 randomized schedules (arrival orders, prompt lengths, budgets):
    paged continuous batching is token-identical to the sync oracle. The
    pool (9 pages x 4 tokens) is deliberately smaller than
    num_slots * max_seq = 144, so some schedules preempt and re-admit."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    preemptions = _assert_differential(model, params, schedules=range(20))
    assert preemptions > 0, "pool was sized to force at least one preemption"


@pytest.mark.parametrize(
    "spec_k", [0, pytest.param(2, marks=pytest.mark.spec),
               pytest.param(4, marks=pytest.mark.spec)])
def test_continuous_server_differential_compressed(rng, spec_k):
    """Differential parity on the ResMoE-SVD store across both restore-free
    kernel paths and both store dtypes, under a pool tight enough to
    preempt mid-schedule — and, at spec_k > 0, with barycenter-draft
    speculative decoding on the paged server against the plain sync
    oracle (the whole matrix again, drafts and rollbacks included).
    # PARITY: fused_kernel/fp32  # PARITY: fused_kernel/int8
    # PARITY: fused_token/fp32   # PARITY: fused_token/int8
    # PARITY: spec/fused_kernel-fp32  # PARITY: spec/fused_kernel-int8
    # PARITY: spec/fused_token-fp32   # PARITY: spec/fused_token-int8
    """
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                        keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    qp = quantize_compressed_params(cp)
    total = 0
    for store in (cp, qp):
        for mode in ("fused_kernel", "fused_token"):
            total += _assert_differential(
                model, store, schedules=[7], apply_mode=mode,
                num_slots=2, max_seq=32, page_size=4, pool_pages=5,
                max_new_override=6, spec_k=spec_k)
    if spec_k == 0:
        # spec rounds emit several tokens per step, so the first request
        # drains before the step-7 arrival and the slots never overlap;
        # preemption *during* speculation is forced separately by
        # test_spec_forced_preemption_mid_speculation.
        assert total > 0, "tight pool should preempt at least once"


def _compressed_mixtral_model():
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                        keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    return cfg, model, cp


@pytest.mark.spec
def test_spec_forced_preemption_mid_speculation(rng):
    """A forced eviction lands between spec rounds while the victim holds
    speculative lookahead pages past its frontier: the release must
    return ALL of them (pool pristine after every schedule — asserted by
    the harness) and the recompute-restore must re-derive the
    interrupted round's tokens bitwise."""
    cfg, model, cp = _compressed_mixtral_model()
    preemptions = _assert_differential(
        model, cp, schedules=[3, 11], apply_mode="fused_kernel",
        num_slots=2, max_seq=32, page_size=4, pool_pages=5,
        preempt_steps=[1], spec_k=4)
    assert preemptions >= 1, "forced preemption must have fired"


@pytest.mark.spec
def test_spec_rejection_at_page_boundary(rng):
    """The hard rollback case: a rejection whose accepted frontier lands
    exactly on a page boundary (slot_pos % page_size == 0) — truncate
    frees the very page the next round's first write needs, so
    _ensure_pages must re-allocate it and the re-derived tokens must
    still match the oracle. The stats counter proves the case fired."""
    cfg, model, cp = _compressed_mixtral_model()
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(3)]
    oracle = [Request(prompt=p, max_new_tokens=8) for p in prompts]
    Server(model, cp, num_slots=2, max_seq=32,
           apply_mode="fused_kernel").serve(oracle)
    reqs = [Request(prompt=p, max_new_tokens=8) for p in prompts]
    cont = ContinuousServer(model, cp, num_slots=2, max_seq=32,
                            page_size=4, pool_pages=5,
                            apply_mode="fused_kernel", spec_k=4)
    cont.serve(reqs)
    assert cont.stats["spec_boundary_rejects"] > 0, cont.stats
    for a, b in zip(oracle, reqs):
        assert a.output == b.output, (a.output, b.output)
    cont.pool.check()
    assert cont.pool.pages_in_use == 0


def test_same_seed_same_samples_non_greedy(rng):
    """The rng-threading pin: sample_tokens splits the key INSIDE the
    helper, so two servers of the same kind given the same seed and
    schedule draw identical non-greedy samples — per-site key handling
    once drifted exactly here. Covers both server kinds."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(3)]

    def run(make):
        reqs = [Request(prompt=p, max_new_tokens=5) for p in prompts]
        make().serve(reqs)
        return [r.output for r in reqs]

    sync = lambda: Server(model, params, num_slots=2, max_seq=32,
                          greedy=False, seed=7)
    cont = lambda: ContinuousServer(model, params, num_slots=2, max_seq=32,
                                    page_size=4, greedy=False, seed=7)
    assert run(sync) == run(sync)
    assert run(cont) == run(cont)


def test_continuous_server_preemption_and_readmission(rng):
    """A schedule built to thrash: more live demand than the pool holds.
    Every request must still finish with the oracle's exact tokens, and
    the preempted-and-readmitted ones must not lose or duplicate tokens."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(4)]
    sync = Server(model, params, num_slots=3, max_seq=48)
    ra = [Request(prompt=p, max_new_tokens=10) for p in prompts]
    sync.serve(ra)
    cont = ContinuousServer(model, params, num_slots=3, max_seq=48,
                            page_size=4, pool_pages=6)
    rb = [Request(prompt=p, max_new_tokens=10) for p in prompts]
    cont.serve(rb)
    assert cont.stats["preemptions"] > 0
    for a, b in zip(ra, rb):
        assert a.output == b.output
        assert len(b.output) == 10


def test_continuous_server_no_padding_on_capacity_dispatched_moe(rng):
    """Prefill padding must not change MoE expert-capacity dispatch: a
    padded prefill computes capacity from the padded token count and lets
    dummy tokens compete for capacity slots, changing which REAL tokens
    drop. MoE models therefore default to UNBUCKETED prefill; this pins
    the scenario that diverged under padding (long skewed prompt on the
    dispatched path, capacity_factor low enough that a few extra tokens
    cross a capacity step)."""
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg,
        moe=dataclasses.replace(cfg.moe, capacity_factor=1.7),
        resmoe=dataclasses.replace(cfg.resmoe, method="svd", keep_ratio=0.5))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    # skewed routing: one repeated token, length NOT a page multiple
    prompt = np.full(18, int(rng.integers(0, cfg.vocab_size)), np.int32)
    ra, rb = (Request(prompt=prompt, max_new_tokens=5) for _ in range(2))
    Server(model, cp, num_slots=2, max_seq=64, apply_mode="fused").serve([ra])
    cont = ContinuousServer(model, cp, num_slots=2, max_seq=64, page_size=4,
                            apply_mode="fused")
    assert cont.prefill_bucket == 1  # MoE models must not pad by default
    cont.serve([rb])
    assert ra.output == rb.output, (ra.output, rb.output)


def test_continuous_server_preempt_at_cache_boundary(rng):
    """A request preempted at slot_pos == max_seq - 1 resumes with exactly
    max_seq tokens — its prefill fills the whole cache and must FINISH at
    admit (it used to re-enter the decode loop with no writable position:
    an IndexError past the block table when page_size divides max_seq, a
    silent overrun otherwise), still matching the oracle token-for-token."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    max_seq = 8
    short = rng.integers(0, cfg.vocab_size, size=(4,)).astype(np.int32)
    long = rng.integers(0, cfg.vocab_size, size=(7,)).astype(np.int32)
    reqs = [Request(prompt=short, max_new_tokens=4),
            Request(prompt=long, max_new_tokens=3)]
    oracle = [Request(prompt=short, max_new_tokens=4),
              Request(prompt=long, max_new_tokens=3)]
    Server(model, params, num_slots=2, max_seq=max_seq).serve(oracle)
    # 3 pages of 4: the long request (most recently admitted, at
    # slot_pos = 7 == max_seq - 1 when the short one needs its 2nd page)
    # gets preempted holding a full-cache resume prompt
    cont = ContinuousServer(model, params, num_slots=2, max_seq=max_seq,
                            page_size=4, pool_pages=3)
    cont.serve(reqs)
    assert cont.stats["preemptions"] > 0
    for a, b in zip(oracle, reqs):
        assert a.output == b.output, (a.output, b.output)
    cont.pool.check()
    assert cont.pool.pages_in_use == 0


def test_continuous_server_prompt_at_boundary(rng):
    """Admission edge: a prompt of exactly max_seq - 1 tokens is the
    longest admissible prompt; it prefills, decodes the single remaining
    cache position, and matches the oracle."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    max_seq = 16
    prompt = rng.integers(0, cfg.vocab_size,
                          size=(max_seq - 1,)).astype(np.int32)
    ra, rb = (Request(prompt=prompt, max_new_tokens=5) for _ in range(2))
    Server(model, params, num_slots=2, max_seq=max_seq).serve([ra])
    cont = ContinuousServer(model, params, num_slots=2, max_seq=max_seq,
                            page_size=4)
    cont.serve([rb])
    # prefill emits one token, the last cache position one more
    assert rb.output == ra.output and len(rb.output) == 2
    # one past the boundary is rejected by both servers
    too_long = rng.integers(0, cfg.vocab_size,
                            size=(max_seq,)).astype(np.int32)
    with pytest.raises(ValueError, match="max_seq"):
        cont.serve([Request(prompt=too_long, max_new_tokens=2)])
    assert cont.pool.pages_in_use == 0  # nothing half-admitted


def test_empty_prompt_rejected_even_with_truncation(rng):
    """Admission edge: an empty prompt — as sent, or truncated to nothing
    by max_seq=1 — raises a clear error instead of tracing a [1, 0]
    prefill, on both servers."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    empty = np.zeros((0,), np.int32)
    tok = rng.integers(0, cfg.vocab_size, size=(3,)).astype(np.int32)
    for server in (Server(model, params, num_slots=2, max_seq=16,
                          truncate_prompts=True),
                   ContinuousServer(model, params, num_slots=2, max_seq=16,
                                    page_size=4, truncate_prompts=True)):
        with pytest.raises(ValueError, match="empty prompt"):
            server.serve([Request(prompt=empty, max_new_tokens=2)])
    # truncation that keeps zero tokens (max_seq == 1) lands in the same
    # error — not a crash inside prefill
    crush = ContinuousServer(model, params, num_slots=2, max_seq=1,
                             page_size=4, truncate_prompts=True)
    with pytest.raises(ValueError, match="empty prompt"):
        crush.serve([Request(prompt=tok, max_new_tokens=2)])


def test_continuous_server_demand_exceeding_pool_is_rejected(rng):
    """Admission edge: a request whose lifetime page demand exceeds the
    whole pool fails fast with a clear error (the scheduler could never
    satisfy it — preemption would spin forever), and the server stays
    serviceable."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cont = ContinuousServer(model, params, num_slots=2, max_seq=48,
                            page_size=4, pool_pages=2)  # 8 token positions
    big = Request(prompt=rng.integers(0, cfg.vocab_size, size=(6,))
                  .astype(np.int32), max_new_tokens=10)  # needs 4 pages
    with pytest.raises(ValueError, match="pool"):
        cont.serve([big])
    assert cont.pool.pages_in_use == 0 and all(cont.slot_free)
    ok = Request(prompt=rng.integers(0, cfg.vocab_size, size=(4,))
                 .astype(np.int32), max_new_tokens=3)
    cont.serve([ok])  # fits in 2 pages: 4 prompt + 2 decode positions
    assert len(ok.output) == 3


# ---------------------------------------------------------------------------
# Architecture-matrix ("zoo") differential suite: every mixer kind serves
# through ContinuousServer token-identically to the sync oracle, including
# at least one forced preemption-restore per architecture (ci.sh zoo tier).
# ---------------------------------------------------------------------------


ZOO = [
    "granite-8b",            # pure GQA, global attention
    "gemma3-27b",            # GQA, sliding local / global mix
    "deepseek-v3-671b",      # MLA + MoE
    "rwkv6-1.6b",            # pure recurrent (rwkv6)
    "recurrentgemma-9b",     # hybrid rec-rec-attn (rglru + sliding gqa)
    "recurrentgemma-9b+resmoe",  # hybrid + compressed-MoE fused serving
    "deepseek-v3-671b+resmoe",   # MLA + compressed-MoE fused serving
]

# zoo entries barycenter-draft speculation can serve: a compressed store
# (the center IS the draft model) and no recurrent mixers (their O(1)
# state cannot roll back past a rejected draft). Everything else must
# REFUSE spec_k > 0 with a clear error — asserted below.
ZOO_SPEC = {"deepseek-v3-671b+resmoe"}


def _zoo_model(arch):
    """Build (model, params, apply_mode) for one zoo matrix entry."""
    cfg = reduced_config(arch.split("+")[0])
    apply_mode = None
    if cfg.is_moe:
        # free decode slots run garbage tokens that would otherwise compete
        # with real tokens for expert capacity; widen so batch composition
        # can never change which real tokens are dropped
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    if arch.endswith("+resmoe"):
        cfg = dataclasses.replace(
            cfg,
            moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=128,
                          capacity_factor=8.0),
            resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                       keep_ratio=0.5))
        apply_mode = "fused"
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    if arch.endswith("+resmoe"):
        params, _ = compress_model_params(params, cfg)
    return model, params, apply_mode


@pytest.mark.zoo
@pytest.mark.parametrize(
    "spec_k", [0, pytest.param(2, marks=pytest.mark.spec),
               pytest.param(4, marks=pytest.mark.spec)])
@pytest.mark.parametrize("arch", ZOO)
def test_continuous_server_differential_zoo(arch, spec_k):
    """Differential parity across the whole architecture matrix, with a
    FORCED preemption at step 1 of the first schedule: the victim's state
    is dropped (pages freed, recurrent slot zeroed at re-admit) and the
    resume prefill must recompute it token-identically — for recurrent
    mixers that is the bitwise prefill-scan == decode-step argument of
    DESIGN.md §11, for attention it is page-table surgery. At spec_k > 0
    the spec-able entries (ZOO_SPEC) run the same differential under
    barycenter-draft speculation; every other entry must refuse loudly
    (no store to draft from, or recurrent state with no rollback axis).
    # PARITY: mixer/gqa   # PARITY: mixer/mla
    # PARITY: mixer/rglru # PARITY: mixer/rwkv
    """
    model, params, apply_mode = _zoo_model(arch)
    if spec_k and arch not in ZOO_SPEC:
        with pytest.raises(ValueError, match="compress|recurrent"):
            ContinuousServer(model, params, num_slots=2, max_seq=48,
                             page_size=4, pool_pages=9,
                             apply_mode=apply_mode, spec_k=spec_k)
        return
    preemptions = _assert_differential(
        model, params, schedules=[3, 11], apply_mode=apply_mode,
        num_slots=2, max_seq=48, page_size=4, pool_pages=9,
        preempt_steps=[1], spec_k=spec_k)
    assert preemptions >= 1, "forced preemption must have fired"


@pytest.mark.zoo
def test_continuous_server_window_reclamation(rng):
    """Sliding-window-only stack: pages whose every key has slid out of the
    window for all future queries are freed MID-FLIGHT (stats count them),
    generation still matches the oracle, and the pool comes back pristine.
    With window=8 and page_size=4, page 0 of a slot dies once its position
    reaches 11 — long generations reclaim several pages per request."""
    cfg = dataclasses.replace(reduced_config("granite-8b"), sliding_window=8)
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(4)]
    ra = [Request(prompt=p, max_new_tokens=14) for p in prompts]
    rb = [Request(prompt=p, max_new_tokens=14) for p in prompts]
    Server(model, params, num_slots=3, max_seq=48).serve(ra)
    cont = ContinuousServer(model, params, num_slots=3, max_seq=48,
                            page_size=4, pool_pages=9)
    assert cont.state.pages.reclaimable
    cont.serve(rb)
    for a, b in zip(ra, rb):
        assert a.output == b.output, (a.output, b.output)
    assert cont.stats["reclaimed_pages"] > 0
    cont.pool.check()
    assert cont.pool.pages_in_use == 0


@pytest.mark.soak
def test_continuous_server_soak(rng):
    """Seeded long-run soak (scripts/ci.sh soak tier): hundreds of small
    requests stream through a tiny pool, forcing constant preemption and
    page reuse. Every request must complete within budget, the pool must
    come back pristine, and a deterministic subset is cross-checked
    against the sync oracle token-for-token."""
    cfg = reduced_config("granite-8b")
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    n = 200
    prompts = [rng.integers(0, cfg.vocab_size, size=(int(rng.choice([3, 5])),))
               .astype(np.int32) for _ in range(n)]
    max_new = [int(rng.integers(1, 7)) for _ in range(n)]
    arrivals = np.sort(rng.poisson(0.5, size=n)).tolist()
    reqs = [Request(prompt=p, max_new_tokens=m)
            for p, m in zip(prompts, max_new)]
    cont = ContinuousServer(model, params, num_slots=4, max_seq=32,
                            page_size=4, pool_pages=8)
    cont.serve(reqs, arrival_steps=arrivals)
    assert cont.stats["preemptions"] > 0, "tiny pool must preempt"
    assert cont.stats["peak_pages_in_use"] == cont.pool.num_pages
    for r in reqs:
        assert 1 <= len(r.output) <= r.max_new_tokens
    cont.pool.check()
    assert cont.pool.pages_in_use == 0
    # oracle cross-check on a deterministic subset
    sync = Server(model, params, num_slots=4, max_seq=32)
    subset = list(range(0, n, 25))
    oracle = [Request(prompt=prompts[i], max_new_tokens=max_new[i])
              for i in subset]
    sync.serve(oracle)
    for i, o in zip(subset, oracle):
        assert reqs[i].output == o.output, (i, reqs[i].output, o.output)
