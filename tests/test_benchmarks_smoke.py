"""Smoke tests for the benchmark suites (satellite of the plan PR).

The benchmark modules are exercised end-to-end at toy scale and must
return finite metrics — a NaN/inf approximation error or perplexity row
means a broken compression path, not a slow machine.
"""
import math
import re

from benchmarks import approx_error, downstream_eval

_METRIC_RE = re.compile(r"(nll|acc|ppl)=([-+0-9.eE]+)")


def _assert_finite_value(label, value):
    if isinstance(value, str):
        pairs = _METRIC_RE.findall(value)
        assert pairs, f"{label}: no metrics parsed from {value!r}"
        for name, num in pairs:
            assert math.isfinite(float(num)), f"{label}: {name}={num}"
    else:
        assert math.isfinite(float(value)), f"{label}: {value}"


def test_approx_error_rows_finite():
    rows = approx_error.run(keep_ratio=0.25, seed=0, verbose=False)
    assert rows, "approx_error.run returned no rows"
    for label, _us, value in rows:
        _assert_finite_value(label, value)
    # both model settings and the ResMoE rows must be present
    labels = {label for label, _, _ in rows}
    assert any("ResMoE(SVD)" in lb for lb in labels)
    assert any(lb.startswith("T1/switch-like/") for lb in labels)
    assert any(lb.startswith("T1/mixtral-like/") for lb in labels)


def test_downstream_eval_rows_finite():
    rows = downstream_eval.run(steps=2, keep=0.25, seed=0)
    assert rows, "downstream_eval.run returned no rows"
    for label, _us, value in rows:
        _assert_finite_value(label, value)
    labels = {label for label, _, _ in rows}
    assert "T3/dense" in labels
    assert any("ResMoE(SVD)" in lb for lb in labels)
