"""Smoke tests for the benchmark suites (satellite of the plan PR).

The benchmark modules are exercised end-to-end at toy scale and must
return finite metrics — a NaN/inf approximation error or perplexity row
means a broken compression path, not a slow machine.
"""
import math
import re

from benchmarks import approx_error, downstream_eval

_METRIC_RE = re.compile(r"(nll|acc|ppl)=([-+0-9.eE]+)")


def _assert_finite_value(label, value):
    if isinstance(value, str):
        pairs = _METRIC_RE.findall(value)
        assert pairs, f"{label}: no metrics parsed from {value!r}"
        for name, num in pairs:
            assert math.isfinite(float(num)), f"{label}: {name}={num}"
    else:
        assert math.isfinite(float(value)), f"{label}: {value}"


def test_approx_error_rows_finite():
    rows = approx_error.run(keep_ratio=0.25, seed=0, verbose=False)
    assert rows, "approx_error.run returned no rows"
    for label, _us, value in rows:
        _assert_finite_value(label, value)
    # both model settings and the ResMoE rows must be present
    labels = {label for label, _, _ in rows}
    assert any("ResMoE(SVD)" in lb for lb in labels)
    assert any(lb.startswith("T1/switch-like/") for lb in labels)
    assert any(lb.startswith("T1/mixtral-like/") for lb in labels)


def test_downstream_eval_rows_finite():
    rows = downstream_eval.run(steps=2, keep=0.25, seed=0)
    assert rows, "downstream_eval.run returned no rows"
    for label, _us, value in rows:
        _assert_finite_value(label, value)
    labels = {label for label, _, _ in rows}
    assert "T3/dense" in labels
    assert any("ResMoE(SVD)" in lb for lb in labels)


def test_rate_sweep_rows_numeric_values():
    """The F4 rows carry the paper-fidelity metric in the VALUE column.

    Regression: the sweep used to emit ``(name, 0, metric)`` — every
    BENCH_*.json row of the family had ``value: 0`` and the number
    buried in the derived string, unusable by trajectory tooling.
    """
    from benchmarks import rate_sweep

    rows = rate_sweep.run(seed=0)
    assert rows, "rate_sweep.run returned no rows"
    for label, value, derived in rows:
        assert label.startswith("F4/rate=")
        v = float(value)
        assert math.isfinite(v) and v > 0, f"{label}: value={value!r}"
        assert isinstance(derived, str) and derived, \
            f"{label}: derived must be provenance text"
    labels = {label for label, _, _ in rows}
    for fam in ("ResMoE(UP)", "UP", "ResMoE(SVD)"):
        assert any(lb.endswith(fam) for lb in labels)


def test_bench_json_rows_numeric_values():
    """run.py's artifact rows always carry the metric in ``value``.

    Suites that still emit ``(name, 0, number)`` (memory/flops analytic
    tables) get the number promoted into ``value`` with the original
    string kept as provenance; textual deriveds stay untouched.
    """
    from benchmarks.run import row_to_json

    promoted = row_to_json(("T10/x/UP", 0, 12.5))
    assert promoted["value"] == 12.5
    assert promoted["derived"] == "12.5"
    sci = row_to_json(("T12/x/dense", 0, "1.234e+09"))
    assert sci["value"] == 1.234e9
    textual = row_to_json(("XL/dense", 0, "nll=1.5"))
    assert textual["value"] == 0 and textual["derived"] == "nll=1.5"
    timed = row_to_json(("T11/forward/dense", 42.5, "note"))
    assert timed["value"] == 42.5 and timed["derived"] == "note"
    bare = row_to_json(("SERVE/x", 3.0))
    assert bare["value"] == 3.0 and bare["derived"] == ""
