"""Ragged per-token decode path: kernel parity, gate behaviour, edge cases.

Covers the decode-path edge cases of DESIGN.md §4.4: T=1, duplicate expert
ids inside a token's top-k, token-path/dispatch-path parity at the
``token_path_max_tokens`` boundary, and the analytic bytes claim.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.kernels import token_lowrank_moe
from repro.kernels.ref import token_lowrank_moe_ref
from repro.models import build_model, compress_model_params
from repro.models.moe import moe_layer, token_path_applicable


def _random_store(rng, e, d, f, r, glu):
    center = {"w1": jnp.asarray(rng.normal(size=(d, f)), jnp.float32),
              "w2": jnp.asarray(rng.normal(size=(f, d)), jnp.float32)}
    v = {"w1": jnp.asarray(rng.normal(size=(e, r, d)), jnp.float32),
         "w2": jnp.asarray(rng.normal(size=(e, r, d)), jnp.float32)}
    if glu:
        center["w3"] = jnp.asarray(rng.normal(size=(d, f)), jnp.float32)
        v["w3"] = jnp.asarray(rng.normal(size=(e, r, d)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(e, f, r)), jnp.float32)
    return center, u, v


def _compressed_cfg(arch="mixtral-8x7b", **moe_kw):
    cfg = reduced_config(arch)
    moe = dataclasses.replace(cfg.moe, capacity_factor=8.0, **moe_kw)
    return dataclasses.replace(
        cfg, moe=moe,
        resmoe=dataclasses.replace(cfg.resmoe, method="svd", keep_ratio=0.5))


def _layer0_store(cfg, seed=1):
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(seed))
    cp, _ = compress_model_params(params, cfg)
    return jax.tree_util.tree_map(
        lambda a: jnp.asarray(a[0]), cp["segments"][0]["slots"][0]["ffn"])


@pytest.mark.parametrize("glu,act", [(True, "silu"), (False, "relu")])
def test_token_kernel_matches_ref(rng, glu, act):
    """fused_token kernel == jnp oracle to fp32 tolerance, GLU and non-GLU."""
    t, k, e, d, f, r = 6, 2, 8, 48, 80, 10
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    gates = jnp.asarray(rng.random((t, k)), jnp.float32)
    center, u, v = _random_store(rng, e, d, f, r, glu)
    got = token_lowrank_moe(x, ids, gates, center, u, v, activation=act)
    ref = token_lowrank_moe_ref(x, ids, gates, center, u, v, activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


def test_token_kernel_t1(rng):
    """T=1 (single live slot) degenerates to a k-step grid and stays exact."""
    e, d, f, r = 4, 32, 64, 6
    x = jnp.asarray(rng.normal(size=(1, d)), jnp.float32)
    ids = jnp.asarray([[2, 0]], jnp.int32)
    gates = jnp.asarray([[0.7, 0.3]], jnp.float32)
    center, u, v = _random_store(rng, e, d, f, r, glu=True)
    got = token_lowrank_moe(x, ids, gates, center, u, v)
    ref = token_lowrank_moe_ref(x, ids, gates, center, u, v)
    assert got.shape == (1, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_token_kernel_duplicate_expert_ids(rng):
    """Duplicate experts within a token's top-k contribute independently:
    gates (g1, g2) on the SAME expert must equal one gate g1+g2."""
    e, d, f, r = 4, 32, 64, 6
    x = jnp.asarray(rng.normal(size=(3, d)), jnp.float32)
    center, u, v = _random_store(rng, e, d, f, r, glu=True)
    ids = jnp.asarray([[1, 1], [0, 3], [2, 2]], jnp.int32)
    gates = jnp.asarray(rng.random((3, 2)), jnp.float32)
    got = token_lowrank_moe(x, ids, gates, center, u, v)
    ref = token_lowrank_moe_ref(x, ids, gates, center, u, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # collapse each duplicated pair onto one slot with the summed gate
    merged_gates = jnp.asarray(
        [[float(gates[0].sum()), 0.0], gates[1], [float(gates[2].sum()), 0.0]],
        jnp.float32)
    merged = token_lowrank_moe(x, ids, merged_gates, center, u, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(merged),
                               rtol=1e-4, atol=1e-4)


def test_fused_token_matches_fused_model(rng):
    """apply_mode='fused_token' == the dispatched fused path through the
    full model (GLU Mixtral config), fp32 tolerance.

    # PARITY: fused_token/fp32
    """
    cfg = _compressed_cfg(token_path_max_tokens=0)  # keep 'fused' dispatched
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(1))
    cp, _ = compress_model_params(params, cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)),
                                   jnp.int32)}
    outs = {}
    for mode in ("fused", "fused_token"):
        logits, _ = jax.jit(
            lambda p, b, m=mode: model.forward(p, b, apply_mode=m))(cp, batch)
        outs[mode] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["fused"], outs["fused_token"],
                               rtol=1e-4, atol=1e-4)


def test_fused_token_matches_fused_nonglu(rng):
    """Same parity on a non-GLU store (switch-base-8: relu, top-1)."""
    cfg = _compressed_cfg("switch-base-8", token_path_max_tokens=0)
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(2))
    cp, _ = compress_model_params(params, cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)),
                                   jnp.int32)}
    outs = {}
    for mode in ("fused", "fused_token"):
        logits, _ = jax.jit(
            lambda p, b, m=mode: model.forward(p, b, apply_mode=m))(cp, batch)
        outs[mode] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["fused"], outs["fused_token"],
                               rtol=1e-4, atol=1e-4)


def test_token_gate_boundary(rng, monkeypatch):
    """The automatic gate switches EXACTLY at token_path_max_tokens, and the
    two paths agree at the boundary."""
    import repro.kernels as kernels_pkg

    thr = 4
    cfg = _compressed_cfg(token_path_max_tokens=thr)
    bank = _layer0_store(cfg)
    m = cfg.moe

    # static gate logic
    assert token_path_applicable(bank, m, "fused", thr)
    assert not token_path_applicable(bank, m, "fused", thr + 1)
    assert token_path_applicable(bank, m, "fused_token", 10_000)  # forced
    assert not token_path_applicable(bank, m, "restored", 1)
    assert not token_path_applicable({"w1": None}, m, "fused", 1)  # dense

    # dynamic: count kernel entries through moe_layer
    calls = []
    orig = kernels_pkg.token_lowrank_moe

    def spy(*args, **kwargs):
        calls.append(1)
        return orig(*args, **kwargs)

    monkeypatch.setattr(kernels_pkg, "token_lowrank_moe", spy)
    x_at = jnp.asarray(rng.normal(size=(thr, 1, cfg.d_model)), jnp.float32)
    x_over = jnp.asarray(rng.normal(size=(thr + 1, 1, cfg.d_model)),
                         jnp.float32)
    out_tok, _ = moe_layer(bank, x_at, cfg, apply_mode="fused")
    assert len(calls) == 1  # at the boundary: token path
    out_disp_over, _ = moe_layer(bank, x_over, cfg, apply_mode="fused")
    assert len(calls) == 1  # one past the boundary: dispatched path

    # parity at the boundary: same inputs through the gate-disabled config
    cfg_disp = dataclasses.replace(
        cfg, moe=dataclasses.replace(m, token_path_max_tokens=0))
    out_disp, _ = moe_layer(bank, x_at, cfg_disp, apply_mode="fused")
    assert len(calls) == 1
    np.testing.assert_allclose(np.asarray(out_tok, np.float32),
                               np.asarray(out_disp, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_fused_token_rejects_delta_store(rng):
    """up/block (dense-delta) stores have no low-rank factors to gather."""
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="up",
                                        keep_ratio=1.0))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    cp, _ = compress_model_params(params, cfg)
    bank = jax.tree_util.tree_map(
        lambda a: jnp.asarray(a[0]), cp["segments"][0]["slots"][0]["ffn"])
    x = jnp.asarray(rng.normal(size=(2, 1, cfg.d_model)), jnp.float32)
    with pytest.raises(ValueError, match="fused_token"):
        moe_layer(bank, x, cfg, apply_mode="fused_token")


def test_token_path_fewer_bytes_at_decode_shapes():
    """Analytic Mixtral-shape accounting: the token path must move strictly
    fewer HBM bytes than the dispatched grouped kernel at T <= 8."""
    runtime = pytest.importorskip("benchmarks.runtime")
    rows = {r[0]: r[1] for r in runtime.token_decode_roofline_mixtral()}
    for t in (1, 4, 8):
        tok = rows[f"T11/token_decode_roofline/T{t}_token_GB"]
        disp = rows[f"T11/token_decode_roofline/T{t}_dispatched_GB"]
        assert tok < disp, (t, tok, disp)
        assert rows[f"T11/token_decode_roofline/T{t}_bytes_x"] > 1.0
