"""Residual compressor unit tests."""
import numpy as np
import pytest

from repro.core.residual import (
    compress_residual,
    compress_svd,
    prune_block,
    prune_unstructured,
    svd_rank_for_ratio,
)


def test_prune_exact_count(rng):
    d = rng.normal(size=(32, 48)).astype(np.float32)
    for ratio in (0.1, 0.25, 0.5, 0.99):
        c = prune_unstructured(d, ratio)
        assert c.nnz == max(1, round(ratio * d.size))
        assert (np.asarray(c.dense) != 0).sum() == c.nnz


def test_prune_keeps_largest(rng):
    d = rng.normal(size=(16, 16)).astype(np.float32)
    c = prune_unstructured(d, 0.25)
    kept = np.abs(c.dense[c.dense != 0])
    dropped = np.abs(d[c.dense == 0])
    assert kept.min() >= dropped.max() - 1e-7


def test_prune_full_is_lossless(rng):
    d = rng.normal(size=(8, 8)).astype(np.float32)
    c = prune_unstructured(d, 1.0)
    np.testing.assert_array_equal(c.to_dense(), d)


def test_block_roundtrip(rng):
    d = rng.normal(size=(32, 256)).astype(np.float32)
    c = prune_block(d, 1.0, block_shape=(8, 128))
    np.testing.assert_allclose(c.to_dense()[:32, :256], d)


def test_block_param_budget(rng):
    d = rng.normal(size=(64, 256)).astype(np.float32)
    c = prune_block(d, 0.25, block_shape=(8, 128))
    total_blocks = (64 // 8) * (256 // 128)
    assert c.block_values.shape[0] == max(1, round(0.25 * total_blocks))


def test_block_keeps_highest_energy(rng):
    d = np.ones((16, 256), np.float32) * 0.01
    d[0:8, 0:128] = 5.0  # one hot block
    c = prune_block(d, 1 / 4, block_shape=(8, 128))
    dense = c.to_dense()
    assert dense[0, 0] == 5.0


def test_svd_rank_formula():
    # Appendix A.4: r*(m+n) ~ ratio*m*n
    m, n, ratio = 128, 384, 0.25
    r = svd_rank_for_ratio(m, n, ratio)
    assert abs(r * (m + n) - ratio * m * n) <= (m + n)


def test_svd_best_rank_k(rng):
    d = rng.normal(size=(24, 40)).astype(np.float64)
    c = compress_svd(d.astype(np.float32), keep_ratio=0.5)
    r = c.u.shape[1]
    # Eckart-Young: error equals sum of discarded squared singular values
    s = np.linalg.svd(d, compute_uv=False)
    best = (s[r:] ** 2).sum()
    got = ((c.to_dense() - d) ** 2).sum()
    np.testing.assert_allclose(got, best, rtol=1e-3)


def test_storage_accounting(rng):
    d = rng.normal(size=(64, 256)).astype(np.float32)
    up = compress_residual(d, "up", 0.25)
    blk = compress_residual(d, "block", 0.25)
    svd = compress_residual(d, "svd", 0.25)
    dense_bytes = d.size * 2
    # UP with CSR int32 indexing costs ~3x its value bytes (paper App. A.7)
    assert up.storage_bytes(2) > 0.25 * dense_bytes
    # block index overhead is tiny: close to the pure value budget
    assert blk.storage_bytes(2) < 0.27 * dense_bytes
    assert svd.storage_bytes(2) <= 0.26 * dense_bytes
    assert up.num_params() == round(0.25 * d.size)
