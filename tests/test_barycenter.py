"""Wasserstein barycenter: objective dominance, Prop 4.1 brute-force check."""
import itertools

import numpy as np

from conftest import make_clustered_design
from repro.core.barycenter import (
    average_center,
    reference_center,
    wasserstein_barycenter,
)


def test_objective_decreases(rng):
    design = make_clustered_design(rng)
    res = wasserstein_barycenter(design, num_iters=8)
    tr = res.objective_trace
    assert all(tr[i + 1] <= tr[i] + 1e-9 for i in range(len(tr) - 1))


def test_wb_dominates_avg_and_reference(rng):
    design = make_clustered_design(rng, noise=0.4, distinct=0.8)
    wb = wasserstein_barycenter(design, num_iters=10)
    avg = average_center(design)
    ref = reference_center(design)
    assert wb.objective <= avg.objective + 1e-9
    assert wb.objective <= ref.objective + 1e-9


def test_perms_are_permutations(rng):
    design = make_clustered_design(rng)
    res = wasserstein_barycenter(design, num_iters=5)
    n, p_i, _ = design.shape
    for k in range(n):
        assert sorted(res.perms[k]) == list(range(p_i))


def test_prop_4_1_brute_force(rng):
    """Proposition 4.1: the WB fixed point solves problem (4).

    Tiny instance (p_I=4) lets us brute-force all permutation tuples: for
    the WB center, per-expert optimal perms from exhaustive search must give
    the same objective as the OT-derived ones, and no (perm..., center=mean)
    combination can beat the WB solution.
    """
    n, p_i, d = 3, 4, 5
    design = make_clustered_design(rng, n_experts=n, p_i=p_i, d=d, noise=0.3)
    wb = wasserstein_barycenter(design, num_iters=20)

    def obj_for(perms):
        center = np.mean([design[k][list(perms[k])] for k in range(n)], axis=0)
        tot = 0.0
        for k in range(n):
            dd = design[k][list(perms[k])] - center
            tot += (dd * dd).sum()
        return tot / n / p_i

    best = np.inf
    for combo in itertools.product(itertools.permutations(range(p_i)), repeat=n):
        best = min(best, obj_for(combo))
    assert wb.objective <= best + 1e-8


def test_recovers_common_pattern_exactly(rng):
    """Pure-permutation experts (no noise): WB objective must hit ~0."""
    base = rng.normal(size=(16, 10))
    design = np.stack([base[rng.permutation(16)] for _ in range(5)])
    wb = wasserstein_barycenter(design, num_iters=10)
    assert wb.objective < 1e-12
