"""Trip-count-aware HLO cost analyzer: exactness probes.

These are the probes that justified replacing XLA:CPU's cost_analysis for
the roofline (it counts while-loop bodies once); they now guard against
regressions in the parser across jax/XLA upgrades.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo_text, xla_cost_analysis

ONE_MATMUL = 2 * 256 ** 3


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def _scan_matmuls(n):
    def f(x, ws):
        def body(c, w):
            return c @ w, 0

        return jax.lax.scan(body, x, ws)[0]

    return _compile(
        f,
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((n, 256, 256), jnp.float32),
    )


def test_plain_matmul_flops():
    comp = _compile(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
    )
    got = analyze_hlo_text(comp.as_text())["flops"]
    assert got == ONE_MATMUL


@pytest.mark.parametrize("n", [1, 4, 16])
def test_scan_multiplies_trip_count(n):
    comp = _scan_matmuls(n)
    got = analyze_hlo_text(comp.as_text())["flops"]
    assert got == n * ONE_MATMUL
    # document the XLA undercount this module exists to fix
    assert xla_cost_analysis(comp)["flops"] == pytest.approx(ONE_MATMUL, rel=0.01)


def test_nested_scan():
    def g(x, ws):
        def outer(c, w2):
            def inner(c2, w):
                return c2 @ w, 0

            return jax.lax.scan(inner, c, w2)[0], 0

        return jax.lax.scan(outer, x, ws)[0]

    comp = _compile(
        g,
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((4, 8, 256, 256), jnp.float32),
    )
    got = analyze_hlo_text(comp.as_text())["flops"]
    assert got == 32 * ONE_MATMUL


def test_grad_of_scan_counts_fwd_and_bwd():
    def f(x, ws):
        def body(c, w):
            return c @ w, 0

        return jax.lax.scan(body, x, ws)[0].sum()

    comp = _compile(
        jax.grad(f, argnums=1),
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((8, 256, 256), jnp.float32),
    )
    got = analyze_hlo_text(comp.as_text())["flops"]
    # 8 fwd + 2x8 bwd matmuls
    assert got == 24 * ONE_MATMUL


def test_bytes_and_collectives_nonnegative():
    comp = _scan_matmuls(4)
    res = analyze_hlo_text(comp.as_text())
    assert res["bytes"] > 4 * 2 * 256 * 256 * 4  # at least the streamed ws
    assert res["coll_total"] == 0  # single device: no collectives
