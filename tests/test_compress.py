"""End-to-end ResMoE compression tests (Table 1 semantics)."""
import numpy as np
import pytest

from conftest import make_bank, make_clustered_design
from repro.core.baselines import ALL_BASELINES, run_baseline
from repro.core.compress import (
    compress_bank,
    design_matrices,
    restored_bank,
    split_design,
)


def _clustered_bank(rng, n=5, d=12, f=16):
    """Bank whose experts share a permuted common pattern (realistic case)."""
    design = make_clustered_design(rng, n_experts=n, p_i=f, d=2 * d + d, noise=0.2)
    # split columns back into w1 [d, f], w3 [d, f], w2 [f, d]
    bank = {"w1": [], "w3": [], "w2": []}
    for k in range(n):
        m = design[k]
        bank["w1"].append(m[:, :d].T)
        bank["w3"].append(m[:, d : 2 * d].T)
        bank["w2"].append(m[:, 2 * d :])
    return {k: np.stack(v).astype(np.float32) for k, v in bank.items()}


def test_design_matrix_roundtrip(rng):
    bank = make_bank(rng)
    design = design_matrices(bank)
    w = split_design(design[1], {k: v[0] for k, v in bank.items()})
    np.testing.assert_allclose(w["w1"], bank["w1"][1])
    np.testing.assert_allclose(w["w3"], bank["w3"][1])
    np.testing.assert_allclose(w["w2"], bank["w2"][1])


def _expert_fn(w, x):
    import jax.nn

    h = jax.nn.silu(x @ w["w1"]) * (x @ w["w3"])
    return np.asarray(h @ w["w2"])


def test_restored_bank_function_equivalence(rng):
    """keep=1.0 UP restore must preserve each expert as a FUNCTION (the
    row/col permutation invariance of Eq. 3)."""
    bank = make_bank(rng, n=3, d=8, f=12)
    comp = compress_bank(bank, method="up", keep_ratio=1.0)
    rb = restored_bank(comp, {k: v[0] for k, v in bank.items()})
    x = rng.normal(size=(5, 8)).astype(np.float32)
    for k in range(3):
        orig = _expert_fn({n: bank[n][k] for n in bank}, x)
        rest = _expert_fn({n: rb[n][k] for n in rb}, x)
        np.testing.assert_allclose(rest, orig, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("method", ["up", "svd", "block"])
def test_resmoe_beats_direct_compression(method, rng):
    """Table 1 core claim: WB-centered residual compression beats direct
    per-expert compression at matched keep ratio (on clustered banks)."""
    bank = _clustered_bank(rng)
    design = design_matrices(bank)
    comp = compress_bank(bank, method=method, keep_ratio=0.25)
    res_err = comp.approximation_error(design)
    direct = run_baseline("up" if method in ("up", "block") else "svd", design, 0.25)
    assert res_err < direct.approximation_error(design)


def test_center_ablation_ordering(rng):
    """Table 4: WB center <= Avg center in approximation error."""
    bank = _clustered_bank(rng)
    design = design_matrices(bank)
    wb = compress_bank(bank, method="up", keep_ratio=0.25, center="wb")
    avg = compress_bank(bank, method="up", keep_ratio=0.25, center="avg")
    assert wb.approximation_error(design) <= avg.approximation_error(design) + 1e-9


def test_all_baselines_run(rng):
    design = make_clustered_design(rng, n_experts=4, p_i=12, d=10)
    for name in ALL_BASELINES:
        r = run_baseline(name, design, 0.25)
        err = r.approximation_error(design)
        assert np.isfinite(err) and err >= 0


def test_restored_design_rejects_malformed_residual(rng):
    """A residual whose dense shape disagrees with the center must raise a
    descriptive error instead of being silently sliced (the old slice
    masked stores compressed against a different bank)."""
    from repro.core.residual import compress_svd

    bank = make_bank(rng, n=3, d=8, f=12)
    comp = compress_bank(bank, method="svd", keep_ratio=0.5)
    # swap in a residual of the wrong shape (an extra design column)
    p, q = comp.center.shape
    bad = rng.normal(size=(p, q + 4)).astype(np.float32)
    comp.residuals[1] = compress_svd(bad, keep_ratio=0.5)
    comp.restored_design(0)  # intact experts still restore
    with pytest.raises(ValueError, match="does not match center"):
        comp.restored_design(1)


def test_restored_design_block_padding_still_restores(rng):
    """The ONE legitimate shape mismatch — the block store's BCSR tile
    padding — keeps restoring (padding stripped, not rejected)."""
    bank = make_bank(rng, n=3, d=8, f=12)  # f=12, dd=28: both tile-padded
    comp = compress_bank(bank, method="block", keep_ratio=0.5)
    for k in range(3):
        assert comp.restored_design(k).shape == comp.center.shape


def test_storage_shrinks(rng):
    bank = make_bank(rng, n=8, d=32, f=64)
    comp = compress_bank(bank, method="svd", keep_ratio=0.25)
    dense_bytes = sum(v.size * 2 for v in bank.values())
    assert comp.storage_bytes(2) < 0.5 * dense_bytes  # center + residuals
