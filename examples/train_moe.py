"""End-to-end driver: train a MoE LM for a few hundred steps, checkpoint,
compress the result with ResMoE, and evaluate zero-shot (paper protocol).

Default is a ~10M-param reduced Mixtral that runs in minutes on CPU;
``--preset 100m`` selects a ~100M config for real hardware.

    PYTHONPATH=src python examples/train_moe.py --steps 300
"""
import argparse
import dataclasses
import logging
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.configs.base import ModelConfig, MoEConfig, ResMoEConfig
from repro.data import make_pipeline
from repro.launch.train import run_training
from repro.models import build_model, compress_model_params


def preset_100m() -> ModelConfig:
    return ModelConfig(
        name="moe-100m", family="moe", num_layers=8, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=1408,
        vocab_size=32000, attention_type="gqa", glu=True,
        moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=1408),
        resmoe=ResMoEConfig(enabled=True, keep_ratio=0.25, method="up",
                            apply_mode="restored"),
        dtype="float32", remat_policy="none",
    )


def eval_nll(model, params, cfg, pipe, steps=4, apply_mode=None):
    fwd = jax.jit(lambda p, b: model.forward(p, b, apply_mode=apply_mode)[0])
    tot = 0.0
    for i in range(9000, 9000 + steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        logits = fwd(params, batch).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][..., None], -1)[..., 0]
        tot += float((lse - gold).mean())
    return tot / steps


def main():
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--preset", choices=["reduced", "100m"], default="reduced")
    ap.add_argument("--keep-ratio", type=float, default=0.25)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    out = run_training(
        "mixtral-8x7b", steps=args.steps, seq_len=args.seq_len,
        global_batch=args.batch, lr=3e-3, ckpt_dir=ckpt, checkpoint_every=100,
    )
    print(f"training done: loss {out['losses'][0][1]:.3f} -> "
          f"{out['losses'][-1][1]:.3f}; checkpoints in {ckpt}")

    cfg = reduced_config("mixtral-8x7b")
    model = build_model(cfg)
    pipe = make_pipeline(cfg, args.seq_len, args.batch)
    params = out["params"]
    base = eval_nll(model, params, cfg, pipe)
    print(f"dense eval NLL: {base:.4f}")

    for meth, mode in [("up", "restored"), ("svd", "fused")]:
        c = dataclasses.replace(
            cfg, resmoe=dataclasses.replace(
                cfg.resmoe, method=meth, keep_ratio=args.keep_ratio,
                apply_mode=mode))
        cp, report = compress_model_params(params, c)
        nll = eval_nll(model, cp, c, pipe, apply_mode=mode)
        print(f"ResMoE({meth}) @{args.keep_ratio:.0%}: {report.summary()}")
        print(f"  zero-shot eval NLL: {nll:.4f} (delta {nll - base:+.4f})")


if __name__ == "__main__":
    main()
