"""Offline compression pipeline: checkpoint -> ResMoE store -> checkpoint.

The production workflow (paper Algorithm 1 as a batch job):
  1. restore a trained checkpoint,
  2. run the barycenter + residual pipeline per MoE layer (reports
     per-layer approximation error and bytes),
  3. write the compressed store as a new checkpoint, ready for serving.

    PYTHONPATH=src python examples/compress_pipeline.py \
        --method svd --keep-ratio 0.25
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.checkpoint import Checkpointer, latest_step
from repro.configs import reduced_config
from repro.launch.train import run_training
from repro.models import build_model, compress_model_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--method", choices=["up", "svd", "block"], default="svd")
    ap.add_argument("--keep-ratio", type=float, default=0.25)
    ap.add_argument("--in-ckpt", default=None,
                    help="existing checkpoint dir (else trains a fresh one)")
    ap.add_argument("--out-ckpt", default=None)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    model = build_model(cfg)

    if args.in_ckpt:
        ck = Checkpointer(args.in_ckpt)
        step = latest_step(args.in_ckpt)
        abs_p, _ = model.abstract_params()
        zeros = jax.tree_util.tree_map(
            lambda s: jax.numpy.zeros(s.shape, s.dtype), abs_p)
        tree, _ = ck.restore(step, {"params": zeros, "opt": {}})
        params = tree["params"]
    else:
        print("no --in-ckpt: training a small model first (60 steps)...")
        out = run_training(args.arch, steps=60, seq_len=64, global_batch=4)
        params = out["params"]

    c = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method=args.method,
                                        keep_ratio=args.keep_ratio))
    compressed, report = compress_model_params(params, c)
    print(report.summary())
    for layer in report.layers:
        print(f"  layer {layer['layer']}: err={layer['approx_error']:.4f} "
              f"{layer['original_bytes']/2**20:.2f} MiB -> "
              f"{layer['compressed_bytes']/2**20:.2f} MiB")

    out_dir = args.out_ckpt or tempfile.mkdtemp(prefix="resmoe_store_")
    ck_out = Checkpointer(out_dir)
    ck_out.save(0, {"params": compressed},
                extra={"resmoe": dict(method=args.method,
                                      keep_ratio=args.keep_ratio)})
    print(f"compressed store written to {out_dir}")


if __name__ == "__main__":
    main()
