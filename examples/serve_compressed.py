"""Serve a ResMoE-compressed model with continuous batching.

Shows the paper's deployment story: the compressed store answers requests
through the restore-free fused path, with outputs compared against the
dense model on identical prompts.

    PYTHONPATH=src python examples/serve_compressed.py --requests 8
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import reduced_config
from repro.configs.base import ResMoEConfig
from repro.launch.serve import Request, Server
from repro.models import build_model, compress_model_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--keep-ratio", type=float, default=0.5)
    ap.add_argument("--apply-mode", default="fused",
                    choices=ResMoEConfig.APPLY_MODES,
                    help="fused_kernel = grouped Pallas kernel hot path")
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                        keep_ratio=args.keep_ratio,
                                        apply_mode=args.apply_mode))
    model = build_model(cfg)
    # compression targets a TRAINED model (the paper's setting): a short
    # training run gives the experts the shared structure ResMoE exploits.
    from repro.launch.train import run_training

    print("training briefly so the experts have learned structure...")
    out = run_training(args.arch, steps=80, seq_len=64, global_batch=4,
                       lr=3e-3)
    params = out["params"]
    compressed, report = compress_model_params(params, cfg)
    print(report.summary())

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(int(rng.integers(4, 12)),))
               .astype(np.int32) for _ in range(args.requests)]

    dense = Server(model, params, num_slots=args.slots, max_seq=128)
    comp = Server(model, compressed, num_slots=args.slots, max_seq=128,
                  apply_mode=args.apply_mode)
    reqs_d = [Request(prompt=p, max_new_tokens=args.max_new) for p in prompts]
    reqs_c = [Request(prompt=p, max_new_tokens=args.max_new) for p in prompts]
    dense.serve(reqs_d)
    comp.serve(reqs_c)
    agree = 0
    total = 0
    for i, (a, b) in enumerate(zip(reqs_d, reqs_c)):
        match = sum(x == y for x, y in zip(a.output, b.output))
        agree += match
        total += len(a.output)
        print(f"req{i}: dense {a.output}\n       comp  {b.output}")
    print(f"token agreement at keep={args.keep_ratio:.0%}: {agree}/{total}")


if __name__ == "__main__":
    main()
