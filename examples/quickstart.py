"""Quickstart: build a small MoE, compress it with ResMoE, compare outputs.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import build_model, compress_model_params

def main():
    # 1. a reduced Mixtral-family MoE (8 experts, top-2)
    cfg = reduced_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                        keep_ratio=0.25, apply_mode="fused"))
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))

    # 2. one-shot, data-agnostic compression (Wasserstein barycenter +
    #    SVD residuals at 25% parameter retention)
    compressed, report = compress_model_params(params, cfg)
    print(report.summary())

    # 3. run both models on the same batch
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)),
                                   jnp.int32)}
    dense_logits, _ = jax.jit(model.forward)(params, batch)
    for mode in ("restored", "fused", "fused_shared"):
        logits, _ = jax.jit(
            lambda p, b, m=mode: model.forward(p, b, apply_mode=m)
        )(compressed, batch)
        err = float(jnp.mean(jnp.abs(logits - dense_logits)))
        print(f"apply_mode={mode:13s} mean |logit delta| = {err:.4f} "
              f"(logit std {float(jnp.std(dense_logits)):.3f})")

    # 4. the paper's headline: residual compression beats direct compression
    from repro.core.baselines import run_baseline
    from repro.core.compress import compress_bank, design_matrices

    f = jax.tree_util.tree_map(np.asarray, params)["segments"][0]["slots"][0]["ffn"]
    bank = {k: f[k][0] for k in ("w1", "w2", "w3")}
    design = design_matrices(bank)
    direct = run_baseline("up", design, 0.25).approximation_error(design)
    resmoe = compress_bank(bank, "up", 0.25).approximation_error(design)
    print(f"approximation error @25%: direct UP {direct:.3f} vs "
          f"ResMoE(UP) {resmoe:.3f}")


if __name__ == "__main__":
    main()
