"""Logical-axis sharding: named axes on params/activations -> PartitionSpec.

Model code never mentions mesh axes.  Params carry *logical* axis names
(recorded at init); activations request hints via :func:`hint`.  A
``ShardingRules`` context maps logical names to mesh axes (or None).  With no
active context every hint is a no-op, so all model code runs unmodified on a
single CPU device.

Logical axes used across the framework:
  batch, seq, embed(d_model), vocab, heads, kv_heads, head_dim, mlp(d_ff),
  experts, expert_cap, layers(stacked scan dim), lru, rank(resmoe), kv_lora,
  q_lora, conv, codebooks, stats, page_table(paged-cache block tables)
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

_state = threading.local()


# Default production rules (see DESIGN.md §5).  ``pod`` is prepended to the
# batch axis automatically when the active mesh has a "pod" axis.
DEFAULT_RULES: Dict[str, Optional[str]] = {
    "batch": "data",
    "seq": None,
    "embed": "data",        # FSDP-style parameter shard of d_model
    "embed_act": None,      # activations keep d_model replicated by default
    "vocab": "model",
    "heads": "model",
    "kv_heads": None,       # often not divisible by model axis -> replicate
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,   # expert inner dim: EP already uses 'model'
    # ResMoE barycenter segments: replicated by default so the EP region's
    # P(None, None) in_spec is a no-op (DESIGN.md §6 — the center is ~1/E
    # of the restored bank). Large-scale GSPMD decode cells may override
    # to "model" to f-shard the center and save HBM at the cost of
    # per-layer gathers.
    "center_mlp": None,
    "expert_cap": "data",
    # flattened (expert-major) dispatch buffers [E*C, d]
    "expert_tok": ("data",),
    "expert_group": None,
    "cache_seq": "model",   # sequence-sharded KV cache for decode
    # paged-cache block tables [num_slots, max_pages]: tiny int32 maps,
    # replicated — also the serving layer's marker axis for table surgery
    "page_table": None,
    "layers": None,
    "lru": "model",
    "kv_lora": None,
    "q_lora": None,
    "rank": None,
    "conv": None,
    "codebooks": None,
    "stats": None,
}


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    rules: Dict[str, Optional[str]]
    # mesh axes that multiply the data-parallel batch dimension
    batch_axes: Tuple[str, ...] = ("data",)

    def _mesh_size(self, entry) -> int:
        if entry is None:
            return 1
        names = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for name in names:
            n *= dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[name]
        return n

    def spec_for(
        self,
        axes: Tuple[Optional[str], ...],
        shape: Optional[Tuple[int, ...]] = None,
    ) -> P:
        """Resolve logical axes to a PartitionSpec.

        Shape-aware: a mesh axis that does not divide the dimension is
        dropped (e.g. 56 heads on a 16-way 'model' axis -> replicated).
        Mesh axes already consumed by an earlier dimension are dropped too.
        """
        parts = []
        used: set = set()
        for i, a in enumerate(axes):
            if a is None:
                parts.append(None)
                continue
            if a == "batch":
                entry = (tuple(self.batch_axes) if len(self.batch_axes) > 1
                         else self.batch_axes[0])
            else:
                entry = self.rules.get(a)
            if entry is None:
                parts.append(None)
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            names = tuple(n for n in names
                          if n not in used and n in self.mesh.axis_names)
            if not names:
                parts.append(None)
                continue
            if shape is not None:
                size = 1
                for n in names:
                    size *= self._mesh_size(n)
                if shape[i] % size != 0:
                    parts.append(None)
                    continue
            used.update(names)
            parts.append(names if len(names) > 1 else names[0])
        return P(*parts)

    def sharding_for(
        self, axes: Tuple[Optional[str], ...], shape: Optional[Tuple[int, ...]] = None
    ) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(axes, shape))


def axis_size(mesh: Mesh, name: str) -> int:
    """Size of one named mesh axis (1 if the axis is absent)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name, 1)


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def shard_map_unchecked(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` with the replication check disabled, across jax versions.

    jax <= 0.4.x: ``jax.experimental.shard_map.shard_map(check_rep=...)``;
    newer jax promotes it to ``jax.shard_map`` and renames the kwarg to
    ``check_vma``. Our regions psum to replicated outputs through
    quantize/dequantize round-trips the checker cannot see through, so the
    check must be off either way.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:  # transitional releases kept check_rep
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)


def split_devices(devices: Sequence[Any], num_groups: int, *,
                  group_size: Optional[int] = None) -> List[List[Any]]:
    """Partition a device list into ``num_groups`` disjoint groups.

    Each serving replica gets one group as its private mesh domain
    (launch/mesh.py::replica_meshes), so replica collectives never share
    links. Groups are contiguous — on real hardware adjacent device ids
    share interconnect, so contiguity keeps each replica's collectives
    local. ``group_size`` defaults to an even split and must not
    oversubscribe the device list.
    """
    if num_groups < 1:
        raise ValueError("split_devices: need at least one group")
    size = group_size if group_size is not None else len(devices) // num_groups
    if size < 1:
        raise ValueError(
            f"split_devices: {len(devices)} devices cannot form "
            f"{num_groups} non-empty groups")
    if num_groups * size > len(devices):
        raise ValueError(
            f"split_devices: {num_groups} groups of {size} need "
            f"{num_groups * size} devices, have {len(devices)}")
    return [list(devices[i * size:(i + 1) * size])
            for i in range(num_groups)]


def make_rules(mesh: Mesh, overrides: Optional[Dict[str, Optional[str]]] = None) -> ShardingRules:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    batch_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    return ShardingRules(mesh=mesh, rules=rules, batch_axes=batch_axes)


def hint(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    """Constrain activation sharding if a rules context is active."""
    r = current_rules()
    if r is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"hint rank mismatch: {x.shape} vs {axes}")
    return jax.lax.with_sharding_constraint(x, r.sharding_for(axes, tuple(x.shape)))


# ---------------------------------------------------------------------------
# Param logical-axis bookkeeping
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LogicalParam:
    """A parameter tagged with logical axis names (pre-split container)."""

    value: Any  # jnp array or ShapeDtypeStruct
    axes: Tuple[Optional[str], ...]


jax.tree_util.register_pytree_node(
    LogicalParam,
    lambda p: ((p.value,), tuple(p.axes)),
    lambda axes, children: LogicalParam(children[0], axes),
)


def is_logical(x: Any) -> bool:
    return isinstance(x, LogicalParam)


def split_logical(tree: PyTree) -> Tuple[PyTree, PyTree]:
    """Split a tree of LogicalParam into (values, axes) trees."""
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_logical)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_logical)
    return values, axes


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def specs_from_axes(axes_tree: PyTree, rules: ShardingRules,
                    values: Optional[PyTree] = None) -> PyTree:
    """Axes tree (+ optional abstract values for divisibility) -> specs."""
    if values is None:
        return jax.tree_util.tree_map(
            lambda axes: rules.spec_for(axes), axes_tree, is_leaf=_is_axes_leaf
        )
    return jax.tree_util.tree_map(
        lambda axes, v: rules.spec_for(axes, tuple(v.shape)),
        axes_tree, values, is_leaf=_is_axes_leaf,
    )


def shardings_from_axes(axes_tree: PyTree, rules: ShardingRules,
                        values: Optional[PyTree] = None) -> PyTree:
    if values is None:
        return jax.tree_util.tree_map(
            lambda axes: rules.sharding_for(axes), axes_tree, is_leaf=_is_axes_leaf
        )
    # values tree has the same structure; zip per-leaf shapes in
    flat_a, td = jax.tree_util.tree_flatten(axes_tree, is_leaf=_is_axes_leaf)
    flat_v = td.flatten_up_to(values)
    return td.unflatten([
        rules.sharding_for(a, tuple(v.shape)) for a, v in zip(flat_a, flat_v)
    ])
