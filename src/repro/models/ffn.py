"""Dense feed-forward layers (MLP / SwiGLU)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import LogicalParam, hint
from .layers import activation_fn, dense_param


def init_ffn(key, d_model: int, d_ff: int, glu: bool, dtype) -> Dict[str, LogicalParam]:
    ks = jax.random.split(key, 3)
    p = {
        "w1": dense_param(ks[0], (d_model, d_ff), ("embed", "mlp"), dtype),
        "w2": dense_param(ks[1], (d_ff, d_model), ("mlp", "embed"), dtype, fan_in=d_ff),
    }
    if glu:
        p["w3"] = dense_param(ks[2], (d_model, d_ff), ("embed", "mlp"), dtype)
    return p


def ffn(params: Dict[str, jnp.ndarray], x: jnp.ndarray, activation: str = "silu") -> jnp.ndarray:
    act = activation_fn(activation)
    h = jnp.einsum("...d,df->...f", x, params["w1"])
    h = act(h)
    if "w3" in params:
        h = h * jnp.einsum("...d,df->...f", x, params["w3"])
    h = hint(h, ("batch", "seq", "mlp")) if h.ndim == 3 else h
    return jnp.einsum("...f,fd->...d", h, params["w2"])
