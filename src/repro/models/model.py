"""Model facade: init/loss/prefill/decode + shape specs + ResMoE adapters."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from ..sharding import LogicalParam, split_logical
from . import transformer as tfm

PyTree = Any


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # -- params ---------------------------------------------------------------

    def init(self, rng) -> PyTree:
        """Concrete params as a LogicalParam tree."""
        return tfm.init_params(rng, self.cfg)

    def init_split(self, rng) -> Tuple[PyTree, PyTree]:
        return split_logical(self.init(rng))

    def abstract_params(self) -> Tuple[PyTree, PyTree]:
        """(ShapeDtypeStruct tree, logical-axes tree) without allocation."""
        tree = jax.eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0), self.cfg))
        values, axes = split_logical(tree)
        return values, axes

    # -- caches ----------------------------------------------------------------

    def init_cache(self, batch: int, max_seq: int) -> PyTree:
        return tfm.init_cache(self.cfg, batch, max_seq)

    def abstract_cache(self, batch: int, max_seq: int) -> Tuple[PyTree, PyTree]:
        tree = jax.eval_shape(lambda: tfm.init_cache(self.cfg, batch, max_seq))
        return split_logical(tree)

    def init_paged_cache(self, batch: int, max_seq: int, page_size: int,
                         num_pages: int) -> PyTree:
        """Paged serving cache: attention layers get page pools + block
        tables, recurrent layers get per-slot state slots; see
        transformer.init_paged_cache and launch/paging.py (DESIGN.md §11)."""
        return tfm.init_paged_cache(self.cfg, batch, max_seq, page_size,
                                    num_pages)

    def serving_layout(self):
        """``(mixer, window)`` per layer — feeds ServingState's per-mixer
        demand accounting in the continuous-batching scheduler."""
        return tfm.mixer_layout(self.cfg)

    # -- compute ---------------------------------------------------------------

    def loss(self, params, batch, remat: bool = True):
        return tfm.loss_fn(params, batch, self.cfg, remat=remat)

    def forward(self, params, batch, apply_mode: Optional[str] = None):
        logits, _, aux = tfm.forward(params, batch, self.cfg, apply_mode=apply_mode)
        return logits, aux

    def prefill(self, params, batch, cache, positions=None, last_only: bool = True,
                apply_mode: Optional[str] = None,
                capacity_per_row: bool = False):
        """Prefill ``batch`` against ``cache``.

        ``capacity_per_row`` makes a multi-row same-length prefill give
        every MoE layer per-batch-row expert capacity (DESIGN.md §13), so
        each row's output matches its own B=1 prefill — the batched
        prefill-insert path of launch/engine.py.
        """
        logits, new_cache, _ = tfm.forward(
            params, batch, self.cfg, cache=cache, positions=positions,
            last_only=last_only, apply_mode=apply_mode,
            capacity_per_row=capacity_per_row,
        )
        return logits, new_cache

    def decode_step(self, params, batch, cache, positions, apply_mode=None):
        """One decode step over the live batch.

        With a ResMoE-SVD store and a restore-free ``apply_mode``, the
        decode token count (B live slots) sits under
        ``MoEConfig.token_path_max_tokens``, so every MoE layer takes the
        ragged capacity-free per-token path (kernels/resmoe_token.py,
        DESIGN.md §4.4) while prefill keeps the dispatched paths.
        """
        logits, new_cache, _ = tfm.forward(
            params, batch, self.cfg, cache=cache, positions=positions,
            apply_mode=apply_mode,
        )
        return logits, new_cache

    # -- input specs (dry-run stand-ins; no allocation) --------------------------

    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        b = shape.global_batch
        s = shape.seq_len
        i32 = jnp.int32
        dt = jnp.bfloat16
        if shape.kind == "train":
            if cfg.frontend == "vision":
                p = cfg.num_prefix_embeddings
                st = s - p
                return {
                    "patch_embeddings": jax.ShapeDtypeStruct((b, p, cfg.d_model), dt),
                    "tokens": jax.ShapeDtypeStruct((b, st), i32),
                    "labels": jax.ShapeDtypeStruct((b, st), i32),
                }
            if cfg.frontend == "audio":
                return {
                    "frame_embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                    "labels": jax.ShapeDtypeStruct((b, s, cfg.num_codebooks), i32),
                }
            return {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        if shape.kind == "prefill":
            if cfg.frontend == "vision":
                p = cfg.num_prefix_embeddings
                return {
                    "patch_embeddings": jax.ShapeDtypeStruct((b, p, cfg.d_model), dt),
                    "tokens": jax.ShapeDtypeStruct((b, s - p), i32),
                }
            if cfg.frontend == "audio":
                return {"frame_embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        # decode: one new token against a seq_len-deep cache
        if cfg.frontend == "audio":
            return {"frame_embeddings": jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}

    def decode_positions_spec(self, shape: ShapeConfig) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def abstract_compressed_params(
    cfg: ModelConfig, store_dtype: str = "fp32"
) -> Tuple[PyTree, PyTree]:
    """ShapeDtypeStruct tree of the ResMoE-SVD compressed store (+ axes).

    Mirrors what compress_model_params produces, without running the
    barycenter — used by the dry-run to lower compressed serving at full
    scale. Only method='svd' stores are supported abstractly (up/block keep
    dense deltas and change no shapes worth dry-running).

    ``store_dtype="int8"`` mirrors :func:`quantize_compressed_params`
    instead: int8 center/u/v plus fp32 per-channel scale leaves
    (center scales on the output-channel axis, rank scales [E, r]).

    A per-layer :class:`~repro.core.plan.CompressionPlan` on
    ``cfg.resmoe.plan`` makes the store heterogeneous: each MoE slot's
    rank, store dtype and kept-expert count follow its LayerSpec recipe
    (``store_dtype`` stays the fallback for recipe-less slots), and
    trimmed slots gain the int32 ``expert_map`` remap leaf.
    """
    import jax

    from ..core.quant import STORE_DTYPES
    from ..core.residual import svd_rank_for_ratio

    if cfg.resmoe.method != "svd":
        raise ValueError("abstract compressed store: method must be 'svd'")
    if store_dtype not in STORE_DTYPES:
        raise ValueError(f"store_dtype {store_dtype!r} not in {STORE_DTYPES}")
    from ..sharding import split_logical

    tree = jax.eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    values, axes = split_logical(tree)
    m = cfg.moe
    d, f = cfg.d_model, m.expert_d_ff
    dd = (3 * d) if cfg.glu else (2 * d)
    r_default = svd_rank_for_ratio(f, dd, cfg.resmoe.keep_ratio)

    # same cfg -> same segmentation as the eval_shape tree above, so the
    # layer plan walks in lockstep with the param segments
    plan_segs = tfm.build_plan(cfg)
    for seg_v, seg_a, seg in zip(values["segments"], axes["segments"],
                                 plan_segs):
        for slot_v, slot_a, spec in zip(seg_v["slots"], seg_a["slots"],
                                        seg.pattern):
            ffn_v = slot_v.get("ffn")
            if not (isinstance(ffn_v, dict) and "router" in ffn_v
                    and "w1" in ffn_v):
                continue
            rec = spec.recipe
            r = (rec.rank if rec is not None and rec.rank is not None
                 else r_default)
            quant = ((rec.store_dtype if rec is not None else store_dtype)
                     == "int8")
            f32 = jnp.int8 if quant else jnp.bfloat16  # serving store dtype
            stacked = len(ffn_v["w1"].shape) == 4
            lead = ffn_v["w1"].shape[:1] if stacked else ()
            e_orig = ffn_v["w1"].shape[1 if stacked else 0]
            e = e_orig - (len(rec.drop_experts) if rec is not None else 0)
            lax = ("layers",) if stacked else ()
            center_v = {
                "w1": jax.ShapeDtypeStruct(lead + (d, f), f32),
                "w2": jax.ShapeDtypeStruct(lead + (f, d), f32),
            }
            # center: NEVER data-sharded on d (that caused per-layer psums
            # on deepseek decode). The f dim carries its own logical axis —
            # replicated under the default rules so the EP region's
            # P(None, None) center in_spec inserts no gathers (DESIGN.md
            # §6); override "center_mlp"->"model" to f-shard it instead.
            center_a = {
                "w1": lax + (None, "center_mlp"),
                "w2": lax + ("center_mlp", None),
            }
            v_v = {
                "w1": jax.ShapeDtypeStruct(lead + (e, r, d), f32),
                "w2": jax.ShapeDtypeStruct(lead + (e, r, d), f32),
            }
            v_a = {
                "w1": lax + ("experts", "rank", "embed"),
                "w2": lax + ("experts", "rank", "embed"),
            }
            if cfg.glu:
                center_v["w3"] = jax.ShapeDtypeStruct(lead + (d, f), f32)
                center_a["w3"] = lax + (None, "center_mlp")
                v_v["w3"] = jax.ShapeDtypeStruct(lead + (e, r, d), f32)
                v_a["w3"] = lax + ("experts", "rank", "embed")
            for k in ("w1", "w2", "w3"):
                slot_v["ffn"].pop(k, None)
                slot_a["ffn"].pop(k, None)
            slot_v["ffn"]["center"] = center_v
            slot_a["ffn"]["center"] = center_a
            slot_v["ffn"]["u"] = jax.ShapeDtypeStruct(lead + (e, f, r), f32)
            slot_a["ffn"]["u"] = lax + ("experts", "expert_mlp", "rank")
            slot_v["ffn"]["v"] = v_v
            slot_a["ffn"]["v"] = v_a
            if e < e_orig:
                # trimmed slot: int32 remap over the ORIGINAL expert axis
                # (routing is untouched); replicated — it is E_orig ints
                slot_v["ffn"]["expert_map"] = jax.ShapeDtypeStruct(
                    lead + (e_orig,), jnp.int32)
                slot_a["ffn"]["expert_map"] = lax + (None,)
            if quant:
                sf = jnp.float32
                slot_v["ffn"]["center_scale"] = {
                    "w1": jax.ShapeDtypeStruct(lead + (f,), sf),
                    "w2": jax.ShapeDtypeStruct(lead + (d,), sf),
                }
                slot_a["ffn"]["center_scale"] = {
                    "w1": lax + ("center_mlp",),
                    "w2": lax + (None,),
                }
                slot_v["ffn"]["u_scale"] = jax.ShapeDtypeStruct(
                    lead + (e, r), sf)
                slot_a["ffn"]["u_scale"] = lax + ("experts", "rank")
                slot_v["ffn"]["v_scale"] = {
                    "w1": jax.ShapeDtypeStruct(lead + (e, r), sf),
                    "w2": jax.ShapeDtypeStruct(lead + (e, r), sf),
                }
                slot_a["ffn"]["v_scale"] = {
                    "w1": lax + ("experts", "rank"),
                    "w2": lax + ("experts", "rank"),
                }
                if cfg.glu:
                    slot_v["ffn"]["center_scale"]["w3"] = \
                        jax.ShapeDtypeStruct(lead + (f,), sf)
                    slot_a["ffn"]["center_scale"]["w3"] = \
                        lax + ("center_mlp",)
                    slot_v["ffn"]["v_scale"]["w3"] = jax.ShapeDtypeStruct(
                        lead + (e, r), sf)
                    slot_a["ffn"]["v_scale"]["w3"] = \
                        lax + ("experts", "rank")
    return values, axes


# ---------------------------------------------------------------------------
# ResMoE <-> model param adapters
# ---------------------------------------------------------------------------

_EXPERT_KEYS = ("w1", "w2", "w3", "b1", "b3")


def iter_moe_banks(params: PyTree):
    """Yield (segment_idx, slot_idx, ffn_dict, stacked: bool) for MoE slots."""
    for si, seg in enumerate(params["segments"]):
        for li, slot in enumerate(seg["slots"]):
            f = slot.get("ffn")
            if isinstance(f, dict) and "router" in f and "w1" in f:
                stacked = np.ndim(f["w1"]) == 4  # [R, E, d, ff]
                yield si, li, f, stacked


def iter_compressed_stores(params: PyTree):
    """Yield (segment_idx, slot_idx, ffn_dict) for compressed MoE slots."""
    for si, seg in enumerate(params["segments"]):
        for li, slot in enumerate(seg["slots"]):
            f = slot.get("ffn")
            if isinstance(f, dict) and "router" in f and "center" in f:
                yield si, li, f


def quantize_compressed_params(params: PyTree) -> PyTree:
    """int8-quantize every compressed SVD store in a params tree.

    Offline (host numpy) step of the compress-once/serve-many pipeline:
    ``compress_model_params`` -> this -> ``checkpoint.save_compressed_store``.
    Dense-delta (up/block) stores are rejected — they have no factored
    form for the dequant-fused kernels.
    """
    from ..core.quant import quantize_store

    params = jax.tree_util.tree_map(lambda x: np.asarray(x), params)
    n = 0
    for si, li, f in iter_compressed_stores(params):
        if "delta" in f:
            raise ValueError(
                "int8 store requires method='svd' (dense-delta up/block "
                f"stores cannot be dequant-fused); segment {si} slot {li}")
        new = quantize_store(f)
        f.clear()
        f.update(new)
        n += 1
    if n == 0:
        raise ValueError("quantize_compressed_params: no compressed stores "
                         "found — run compress_model_params first")
    return params


def compress_model_params(params: PyTree, cfg: ModelConfig, center: str = "wb"):
    """Replace every MoE expert bank with its ResMoE compressed store.

    Works on concrete (host) params; returns (new_params, report).
    ``params`` must be the DENSE model's params — with a per-layer plan on
    ``cfg.resmoe.plan`` that means the params of ``cfg`` with the plan
    stripped (the plan reshapes the layer list, so the dense and planned
    trees segment differently).
    """
    from ..core.api import CompressionReport, ResMoECompressor
    from ..core.compress import design_matrices

    if cfg.resmoe.plan is not None:
        return _compress_with_plan(params, cfg, center)

    rcfg = cfg.resmoe
    comp = ResMoECompressor(rcfg, center=center)
    params = jax.tree_util.tree_map(np.asarray, params)
    reports = []
    errs = []
    total_orig = 0
    total_comp = 0
    layer_counter = 0

    for si, li, f, stacked in iter_moe_banks(params):
        reps = f["w1"].shape[0] if stacked else 1
        new_layers = []
        for r in range(reps):
            bank = {
                k: (f[k][r] if stacked else f[k]) for k in _EXPERT_KEYS if k in f
            }
            orig_bytes = sum(int(v.size) * 2 for v in bank.values())
            total_orig += orig_bytes
            if layer_counter < rcfg.first_layer:
                new_layers.append(None)
                total_comp += orig_bytes
                layer_counter += 1
                continue
            lc = comp.compress_bank(bank, seed=layer_counter)
            err = lc.approximation_error(design_matrices(bank))
            cb = lc.storage_bytes(2)
            reports.append(dict(layer=layer_counter, approx_error=err,
                                original_bytes=orig_bytes, compressed_bytes=cb))
            errs.append(err)
            total_comp += cb
            new_layers.append((lc, bank))
            layer_counter += 1
        _install_store(f, new_layers, rcfg, stacked)

    report = CompressionReport(
        layers=reports, original_bytes=total_orig, compressed_bytes=total_comp,
        mean_approx_error=float(np.mean(errs)) if errs else 0.0,
    )
    return params, report


def _unstack_segments(segments, plan) -> list:
    """Flatten segment params into per-layer dicts in execution order
    (per segment: rep-major, then slot — matching run_segments)."""
    flat = []
    for seg_params, seg in zip(segments, plan):
        for r in range(seg.repeats):
            for slot in seg_params["slots"]:
                if seg.repeats > 1:
                    flat.append(jax.tree_util.tree_map(
                        lambda x, r=r: np.asarray(x)[r], slot))
                else:
                    flat.append(slot)
    return flat


def _restack_segments(layers: list, plan) -> list:
    """Inverse of :func:`_unstack_segments` for a (possibly different)
    segment plan — equal-recipe runs re-stack for scan, so every stacked
    leaf keeps a uniform shape (heterogeneous recipes were already split
    into separate segments by LayerSpec equality in build_plan)."""
    segments = []
    i = 0
    for seg in plan:
        p = len(seg.pattern)
        chunk = layers[i:i + seg.num_layers]
        i += seg.num_layers
        slots = []
        for sl in range(p):
            reps = [chunk[r * p + sl] for r in range(seg.repeats)]
            if seg.repeats > 1:
                slots.append(jax.tree_util.tree_map(
                    lambda *xs: np.stack(xs), *reps))
            else:
                slots.append(reps[0])
        segments.append({"slots": slots})
    if i != len(layers):
        raise ValueError(
            f"segment plan covers {i} layers but {len(layers)} were "
            "produced — the compression plan and model config disagree")
    return segments


def _compress_with_plan(params: PyTree, cfg: ModelConfig, center: str):
    """Per-layer-plan compression: dense params -> heterogeneous store.

    Unstacks the dense tree into flat per-layer blocks, compresses each
    MoE layer under its recipe (rank override, expert trim via the
    ``expert_map`` remap, per-layer int8 quantization), skips dropped
    blocks, then restacks along the PLANNED segmentation.
    """
    from ..core.api import CompressionReport
    from ..core.compress import compress_bank, design_matrices, fused_params
    from ..core.quant import quantize_store

    rcfg = cfg.resmoe
    plan = rcfg.plan
    if rcfg.method != "svd":
        raise ValueError(
            "per-layer compression plans require method='svd' (dense-delta "
            "up/block stores have no factored form to trim or re-rank)")
    if rcfg.first_layer:
        raise ValueError(
            "first_layer > 0 with a plan is ambiguous — express skipped "
            "layers in the plan itself (there is no 'leave dense' recipe; "
            "keep rank high for layers that must stay near-lossless)")

    base_cfg = dataclasses.replace(
        cfg, resmoe=dataclasses.replace(rcfg, plan=None))
    params = jax.tree_util.tree_map(np.asarray, params)
    flat = _unstack_segments(params["segments"], tfm.build_plan(base_cfg))
    base_specs = tfm.layer_specs(base_cfg)
    if not (len(flat) == len(base_specs) == plan.num_layers):
        raise ValueError(
            f"plan/model mismatch: {len(flat)} dense layers, "
            f"{len(base_specs)} specs, {plan.num_layers} recipes")

    reports, errs = [], []
    total_orig = total_comp = 0
    kept_layers = []
    for i, (layer, spec, rec) in enumerate(zip(flat, base_specs,
                                               plan.recipes)):
        if rec.drop_block:
            continue
        if spec.ffn != "moe":
            kept_layers.append(layer)
            continue
        f = dict(layer["ffn"])
        bank = {k: f[k] for k in _EXPERT_KEYS if k in f}
        orig_bytes = sum(int(v.size) * 2 for v in bank.values())
        total_orig += orig_bytes
        lc = compress_bank(
            bank, method="svd", keep_ratio=rcfg.keep_ratio, center=center,
            barycenter_iters=rcfg.barycenter_iters, ot_solver=rcfg.ot_solver,
            seed=i, rank=rec.rank,
        )
        err = lc.approximation_error(design_matrices(bank))
        fp = fused_params(lc, bank)
        store: Dict[str, Any] = {
            "center": {k: x.astype(np.float32) for k, x in fp.center.items()},
            "u": fp.u.astype(np.float32),
            "v": {k: x.astype(np.float32) for k, x in fp.v.items()},
        }
        if rec.drop_experts:
            e = fp.u.shape[0]
            kept = np.asarray(
                [k for k in range(e) if k not in set(rec.drop_experts)])
            emap = np.full((e,), -1, np.int32)
            emap[kept] = np.arange(len(kept), dtype=np.int32)
            store["u"] = store["u"][kept]
            store["v"] = {k: x[kept] for k, x in store["v"].items()}
            store["expert_map"] = emap
        if rec.store_dtype == "int8":
            store = quantize_store(store)
        for k in _EXPERT_KEYS:
            f.pop(k, None)
        f.update(store)
        cb = sum(int(np.asarray(v).size) * np.asarray(v).dtype.itemsize
                 for v in jax.tree_util.tree_leaves(store))
        reports.append(dict(layer=i, approx_error=err,
                            original_bytes=orig_bytes, compressed_bytes=cb))
        errs.append(err)
        total_comp += cb
        new_layer = dict(layer)
        new_layer["ffn"] = f
        kept_layers.append(new_layer)

    params = dict(params)
    params["segments"] = _restack_segments(kept_layers, tfm.build_plan(cfg))
    report = CompressionReport(
        layers=reports, original_bytes=total_orig,
        compressed_bytes=total_comp,
        mean_approx_error=float(np.mean(errs)) if errs else 0.0,
    )
    return params, report


def block_hidden_similarities(params: PyTree, cfg: ModelConfig,
                              tokens: np.ndarray) -> list:
    """Per-block mean token cosine between block input and output.

    The capture side of the block-drop recipe (core/trim.py): runs embed +
    every block once (no cache, full-sequence positions) on concrete
    (split) params and scores how little each block rotates the residual
    stream. Feed the result to ``core.trim.select_dropped_blocks``.
    """
    from ..core.trim import hidden_state_similarity

    specs = tfm.layer_specs(cfg)
    flat = _unstack_segments(params["segments"], tfm.build_plan(cfg))
    tokens = jnp.asarray(tokens)
    b, s = tokens.shape
    x = tfm.embed_inputs(params, {"tokens": tokens}, cfg)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    sims = []
    for layer, spec in zip(flat, specs):
        y, _, _ = tfm.apply_block(layer, x, spec, cfg, positions, cache=None)
        sims.append(hidden_state_similarity(
            np.asarray(jnp.asarray(x, jnp.float32)),
            np.asarray(jnp.asarray(y, jnp.float32))))
        x = y
    return sims


def _install_store(f: Dict[str, Any], new_layers, rcfg, stacked: bool):
    """Mutate the ffn dict in place: expert weights -> compressed store."""
    from ..core.compress import fused_params, split_design

    if any(nl is None for nl in new_layers):
        raise NotImplementedError(
            "first_layer>0 within a scanned stack requires per-layer apply "
            "modes; compress the whole stack or set scan_layers=False."
        )
    if rcfg.method == "svd":
        fused = [fused_params(lc, bank) for (lc, bank) in new_layers]
        rank = max(fp.rank for fp in fused)
        def pad_u(fp):
            return np.pad(fp.u, ((0, 0), (0, 0), (0, rank - fp.rank)))
        def pad_v(v, r):
            return np.pad(v, ((0, 0), (0, rank - r), (0, 0)))
        center = {k: np.stack([fp.center[k] for fp in fused]) for k in fused[0].center}
        u = np.stack([pad_u(fp) for fp in fused])
        v = {k: np.stack([pad_v(fp.v[k], fp.rank) for fp in fused]) for k in fused[0].v}
        if not stacked:
            center = {k: x[0] for k, x in center.items()}
            u = u[0]
            v = {k: x[0] for k, x in v.items()}
        f["center"] = center
        f["u"] = u.astype(np.float32)
        f["v"] = {k: x.astype(np.float32) for k, x in v.items()}
    else:  # up / block -> dense delta store (Algorithm 2 restore path)
        centers, deltas = [], []
        for (lc, bank) in new_layers:
            centers.append(split_design(lc.center, bank))
            dw = [split_design(lc.residuals[k].to_dense()[: lc.center.shape[0],
                                                          : lc.center.shape[1]], bank)
                  for k in range(lc.num_experts)]
            deltas.append({name: np.stack([d[name] for d in dw]) for name in dw[0]})
        center = {k: np.stack([c[k] for c in centers]) for k in centers[0]}
        delta = {k: np.stack([d[k] for d in deltas]) for k in deltas[0]}
        if not stacked:
            center = {k: x[0] for k, x in center.items()}
            delta = {k: x[0] for k, x in delta.items()}
        f["center"] = {k: x.astype(np.float32) for k, x in center.items()}
        f["delta"] = {k: x.astype(np.float32) for k, x in delta.items()}
    for k in _EXPERT_KEYS:
        f.pop(k, None)
