"""Basic layers: norms, RoPE, embeddings, initializers.

All layers are pure functions over explicit param dicts; params are created
through the ``init_*`` helpers which return trees of
:class:`repro.sharding.LogicalParam` so the distribution layer can derive
PartitionSpecs without a second source of truth.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import LogicalParam, hint


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def normal_init(key, shape, scale: float, dtype) -> jnp.ndarray:
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def dense_param(key, shape: Tuple[int, ...], axes, dtype, fan_in: Optional[int] = None) -> LogicalParam:
    fi = fan_in if fan_in is not None else shape[0]
    return LogicalParam(normal_init(key, shape, 1.0 / math.sqrt(max(1, fi)), dtype), axes)


def zeros_param(shape, axes, dtype=jnp.float32) -> LogicalParam:
    return LogicalParam(jnp.zeros(shape, dtype=dtype), axes)


def ones_param(shape, axes, dtype=jnp.float32) -> LogicalParam:
    return LogicalParam(jnp.ones(shape, dtype=dtype), axes)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    # Full f32 elementwise chain. A bf16-rescale variant was tried and
    # REFUTED: +24% HBM traffic on llama3 train under the DESIGN.md §4.3
    # cost model — the extra converts defeat fusion.
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int) -> LogicalParam:
    # stored as (weight - 1) like gemma; rms_norm adds the 1 back.
    return zeros_param((d,), ("embed_act",))


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray,  # [B, S, H, D]
    positions: jnp.ndarray,  # [B, S]
    theta,
) -> jnp.ndarray:
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))  # [D/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs[None, None, :]  # [B,S,D/2]
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype) -> LogicalParam:
    # sigma = 1/sqrt(d): unit-variance inputs after the sqrt(d) embed scale
    # AND O(1) logits under tied readout.
    return LogicalParam(normal_init(key, (vocab, d), d ** -0.5, dtype), ("vocab", "embed"))


def embed_tokens(table: jnp.ndarray, tokens: jnp.ndarray, scale: bool = True) -> jnp.ndarray:
    out = jnp.take(table, tokens, axis=0)
    if scale:
        out = out * jnp.asarray(math.sqrt(table.shape[1]), out.dtype)
    return out


def logits_from_embedding(table: jnp.ndarray, x: jnp.ndarray,
                          softcap: float = 0.0) -> jnp.ndarray:
    """Tied-embedding readout: x [..., d] @ table^T -> [..., vocab]."""
    logits = jnp.einsum("...d,vd->...v", x, table)
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def activation_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


def cross_entropy_loss(
    logits: jnp.ndarray,  # [..., V]
    labels: jnp.ndarray,  # [...]
    mask: Optional[jnp.ndarray] = None,
    z_loss: float = 0.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over unmasked tokens (f32 math). Returns (loss, denom)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss > 0.0:
        nll = nll + z_loss * jnp.square(lse)
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = jnp.asarray(nll.size, jnp.float32)
    return nll.sum() / denom, denom
