"""Mixture-of-Experts layer: routing, gather-based dispatch, expert paths.

Three interchangeable expert-compute paths share one router/dispatch:

  * dense            — original expert bank {w1, (w3), w2}: [E, ...]
  * resmoe restored  — paper Algorithm 2: materialize W_c + Delta in-graph,
                       then run the dense path (methods: up/block/svd).
  * resmoe fused     — beyond-paper: never materialize the restored bank;
                       y = x@Wc + (x@V^T)@U^T per segment (method: svd).
                       ``fused_shared`` additionally computes the two big
                       center matmuls ONCE per token before dispatch (they
                       are expert-independent), removing (k-1)/k of the
                       center FLOPs for top-k routing.
                       ``fused_kernel`` runs the same math on the grouped
                       Pallas kernel (kernels/resmoe_grouped.py): one
                       pallas_call per segment over the whole [E, C, d]
                       dispatch buffer, the shared center tile streamed
                       HBM->VMEM once per output tile and the per-expert
                       low-rank factors accumulated in VMEM scratch
                       (DESIGN.md §4.2) — the prefill serving hot path.
                       ``fused_token`` skips dispatch entirely: a ragged
                       capacity-free per-token kernel
                       (kernels/resmoe_token.py) gathers only each token's
                       top-k experts' low-rank factors and computes every
                       shared-center product once per token — the decode
                       hot path (DESIGN.md §4.4). Restore-free modes take
                       it automatically when the token batch is at most
                       ``MoEConfig.token_path_max_tokens``.

Dispatch is sort/gather-based (MaxText-style "sparse matmul" path): tokens
are sorted by expert id, padded to a static per-expert capacity, processed
with grouped einsums, and combined with a scatter-add. This keeps HLO FLOPs
proportional to *active* parameters (critical for the roofline analysis).

Store dtype: every path also serves the int8-quantized store (DESIGN.md
§9, detected structurally via core/quant.py::is_quantized_store) —
fused_kernel and the token path run the dequant-fused kernel twins, the
einsum/restored paths dequantize in-graph first.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from ..core.quant import dequantize_store, is_quantized_store
from ..sharding import LogicalParam, hint
from .ffn import ffn, init_ffn
from .layers import activation_fn, dense_param


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig, dtype) -> Dict[str, LogicalParam]:
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_d_ff, m.num_experts
    ks = jax.random.split(key, 8)
    p: Dict[str, LogicalParam] = {
        "router": dense_param(ks[0], (d, e), ("embed", None), jnp.float32),
        "w1": dense_param(ks[1], (e, d, f), ("experts", "embed", "expert_mlp"), dtype),
        "w2": dense_param(ks[2], (e, f, d), ("experts", "expert_mlp", "embed"), dtype, fan_in=f),
    }
    if cfg.glu:
        p["w3"] = dense_param(ks[3], (e, d, f), ("experts", "embed", "expert_mlp"), dtype)
    if m.upcycled_init:
        # Mixtral-style: every expert = expert 0 + 10% relative noise.
        for name in ("w1", "w2", "w3"):
            if name in p:
                w = p[name].value
                base = jnp.broadcast_to(w[:1], w.shape)
                p[name] = LogicalParam(
                    (base + 0.1 * (w - base)).astype(w.dtype), p[name].axes
                )
    if m.router_type == "sigmoid":
        p["router_bias"] = LogicalParam(jnp.zeros((e,), jnp.float32), (None,))
    if m.num_shared_experts:
        p["shared"] = init_ffn(ks[4], d, f * m.num_shared_experts, cfg.glu, dtype)
    if m.dense_residual:
        p["dense"] = init_ffn(ks[5], d, cfg.d_ff, cfg.glu, dtype)
    return p


def expert_capacity(num_tokens: int, m: MoEConfig) -> int:
    cap = int(math.ceil(m.capacity_factor * num_tokens * m.top_k / m.num_experts))
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


# Decode-shape crossover for the ragged per-token path: below this token
# count the capacity-padded dispatch pays for >= E*8 padded rows and E
# center re-reads to process a handful of real tokens, while the token
# path reads the center once per segment (DESIGN.md §4.4;
# benchmarks/runtime.py::token_decode_roofline_mixtral states the bytes).
_TOKEN_PATH_MAX_TOKENS = 8

# Restore-free modes whose math the per-token kernel reproduces exactly.
_TOKEN_PATH_AUTO_MODES = ("fused", "fused_shared", "fused_kernel")


def token_path_applicable(params: Dict, m: MoEConfig, mode: str,
                          num_tokens: int, rules=None) -> bool:
    """True when this layer call should take the ragged per-token path."""
    if not ("center" in params and "u" in params and "v" in params):
        return False  # dense banks and dense-delta (up/block) stores
    if mode == "fused_token":
        return True
    if mode not in _TOKEN_PATH_AUTO_MODES:
        return False  # "restored" keeps the paper's Algorithm 2 semantics
    if rules is not None:
        from ..sharding import axis_size

        mesh = rules.mesh
        if "model" in mesh.axis_names and axis_size(mesh, "model") > 1:
            # the low-rank factors are 'model'-sharded on a mesh; the
            # unpartitioned pallas_call would all-gather the whole factor
            # bank every step — keep the GSPMD dispatch, which shards.
            # (apply_mode="fused_token" above still honors an explicit ask.)
            return False
    thr = (m.token_path_max_tokens if m.token_path_max_tokens is not None
           else _TOKEN_PATH_MAX_TOKENS)
    return num_tokens <= thr


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def route(
    params: Dict[str, jnp.ndarray], x2d: jnp.ndarray, m: MoEConfig
) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Return (expert_ids [T,k], gates [T,k], aux metrics)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), params["router"])
    if m.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + params.get("router_bias", 0.0)  # aux-free balance bias
        gate_vals, expert_ids = jax.lax.top_k(sel, m.top_k)
        gates = jnp.take_along_axis(scores, expert_ids, axis=-1)
        if m.normalize_gates:
            gates = gates / (gates.sum(-1, keepdims=True) + 1e-20)
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-20)
    else:
        gate_vals, expert_ids = jax.lax.top_k(logits, m.top_k)
        probs = jax.nn.softmax(logits, axis=-1)
        if m.normalize_gates:
            gates = jax.nn.softmax(gate_vals, axis=-1)
        else:
            # full-softmax probability of each SELECTED expert — shape [T, k].
            # (A .max(-1) here once collapsed gates to [T, 1] for k>1, making
            # combine_tokens index gates_flat out of bounds — silently
            # clamped by jnp gather.)
            gates = jnp.take_along_axis(probs, expert_ids, axis=-1)

    # Switch-style load-balance loss + router z-loss
    e = m.num_experts
    onehot = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    frac_tokens = onehot.mean(0)
    frac_probs = probs.mean(0)
    aux = {
        "load_balance_loss": e * jnp.sum(frac_tokens * frac_probs),
        "router_z_loss": jnp.mean(
            jnp.square(jax.scipy.special.logsumexp(logits, axis=-1))
        ),
    }
    return expert_ids, gates.astype(x2d.dtype), aux


# ---------------------------------------------------------------------------
# Dispatch / combine (sort + capacity padding)
# ---------------------------------------------------------------------------


def make_dispatch(expert_ids: jnp.ndarray, num_experts: int, capacity: int):
    """Compute gather/scatter indexing for the grouped expert matmuls.

    Returns (token_idx [T*k], dest [T*k], keep [T*k]):
      * xg[dest] = x[token_idx] for kept pairs; dest == E*C for dropped.
    """
    t, k = expert_ids.shape
    flat_e = expert_ids.reshape(-1).astype(jnp.int32)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(num_experts, dtype=jnp.int32))
    slot = jnp.arange(t * k, dtype=jnp.int32) - group_start[sorted_e]
    keep = slot < capacity
    dest = jnp.where(keep, sorted_e * capacity + slot, num_experts * capacity)
    token_idx = sort_idx // k
    return token_idx, dest, keep, sort_idx


def make_dispatch_per_row(expert_ids: jnp.ndarray, batch: int, seq: int,
                          num_experts: int, capacity: int):
    """Per-row dispatch for batched same-length prefill (DESIGN.md §13).

    ``capacity`` is computed from ONE row's token count, and each batch row
    is dispatched independently (vmapped :func:`make_dispatch`), so row
    ``b``'s kept/dropped pairs are exactly what a B=1 dispatch of that row
    would produce — batching prompts can no longer change which tokens a
    capacity-limited expert drops. The row-local indices are then
    globalized onto one ``[E, B*C, d]`` buffer (expert-major so the expert
    compute paths see a contiguous per-expert block of B*C rows):

      token_idx = row * seq + token_idx_row       (rows of x2d)
      dest      = e * (B*C) + row * C + slot      (rows of the buffer)
      sort_idx  = row * seq * k + sort_idx_row    (rows of gates_flat)

    Returns the same (token_idx, dest, keep, sort_idx) contract as
    :func:`make_dispatch` with an effective capacity of ``B*C``.
    """
    k = expert_ids.shape[1]
    ids_r = expert_ids.reshape(batch, seq, k)
    token_idx_r, dest_r, keep_r, sort_idx_r = jax.vmap(
        lambda e: make_dispatch(e, num_experts, capacity))(ids_r)
    row = jnp.arange(batch, dtype=jnp.int32)[:, None]
    token_idx = (token_idx_r + row * seq).reshape(-1)
    # recover (expert, slot) from the row-local dest; dropped pairs sit at
    # the row-local sentinel E*C and map to the global sentinel E*B*C
    e = dest_r // capacity
    slot = dest_r % capacity
    dest = jnp.where(
        keep_r,
        e * (batch * capacity) + row * capacity + slot,
        num_experts * batch * capacity,
    ).reshape(-1)
    keep = keep_r.reshape(-1)
    sort_idx = (sort_idx_r + row * (seq * k)).reshape(-1)
    return token_idx, dest, keep, sort_idx


def dispatch_tokens(x2d: jnp.ndarray, token_idx, dest, keep, num_experts: int,
                    capacity: int) -> jnp.ndarray:
    t, d = x2d.shape
    gathered = x2d[token_idx] * keep[:, None].astype(x2d.dtype)
    gathered = hint(gathered, ("expert_tok", None))
    # dropped rows carry zeros, so scatter-ADD with their dest clamped to row
    # 0 is a no-op — keeps the buffer exactly [E*C, d] (hint-friendly shape).
    dest_c = jnp.where(keep, dest, 0)
    buf = hint(jnp.zeros((num_experts * capacity, d), x2d.dtype), ("expert_tok", None))
    xg = buf.at[dest_c].add(gathered)
    xg = xg.reshape(num_experts, capacity, d)
    return hint(xg, ("experts", "expert_cap", None))


def combine_tokens(
    yg: jnp.ndarray,  # [E, C, d]
    gates_flat: jnp.ndarray,  # [T*k] in (token, k) order
    token_idx,
    dest,
    keep,
    num_tokens: int,
    sort_idx,
) -> jnp.ndarray:
    e, c, d = yg.shape
    yflat = hint(yg.reshape(e * c, d), ("expert_tok", None))
    rows = jnp.where(keep, dest, 0)
    vals = yflat[rows] * keep[:, None].astype(yg.dtype)
    vals = hint(vals, ("expert_tok", None))
    g = gates_flat[sort_idx][:, None].astype(yg.dtype)
    buf = hint(jnp.zeros((num_tokens, d), yg.dtype), ("batch", None))
    out = buf.at[token_idx].add(vals * g)
    return hint(out, ("batch", None))


# ---------------------------------------------------------------------------
# Expert compute paths
# ---------------------------------------------------------------------------


def _dense_expert_ffn(bank, xg: jnp.ndarray, activation: str) -> jnp.ndarray:
    act = activation_fn(activation)
    h = jnp.einsum("ecd,edf->ecf", xg, bank["w1"])
    h = act(h)
    if "w3" in bank:
        h = h * jnp.einsum("ecd,edf->ecf", xg, bank["w3"])
    h = hint(h, ("experts", "expert_cap", "expert_mlp"))
    y = jnp.einsum("ecf,efd->ecd", h, bank["w2"])
    # keep the output d-sharded like w2's d: weights stay stationary and the
    # (tiny) activations reshard, instead of all-gathering the whole w2 bank
    # over 'data' every layer (was 92% of deepseek-decode collective bytes).
    return hint(y, ("experts", "expert_cap", "embed"))


def _restored_bank(params: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Materialize the restored expert bank in-graph (paper Algorithm 2)."""
    c = params["center"]
    out = {}
    if "delta" in params:  # up / block store
        for name in ("w1", "w3", "w2"):
            if name in c:
                out[name] = c[name][None] + params["delta"][name]
    else:  # svd store: delta = u @ v per segment
        u = params["u"]  # [E, f, r]
        for name in ("w1", "w3"):
            if name in c:
                dw = jnp.einsum("efr,erd->edf", u, params["v"][name])
                out[name] = c[name][None] + dw
        dw2 = jnp.einsum("efr,erd->efd", u, params["v"]["w2"])
        out["w2"] = c["w2"][None] + dw2
    return out


def _fused_expert_ffn(params, xg: jnp.ndarray, activation: str,
                      base1: Optional[jnp.ndarray] = None,
                      base3: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Restore-free SVD path: y = x@Wc + (x@V^T)@U^T per segment.

    ``base1``/``base3`` carry pre-dispatch center products for the
    fused_shared variant ([E, C, f], already dispatched).
    """
    act = activation_fn(activation)
    c, u, v = params["center"], params["u"], params["v"]
    if base1 is None:
        base1 = jnp.einsum("ecd,df->ecf", xg, c["w1"])
    tv = jnp.einsum("ecd,erd->ecr", xg, v["w1"])
    h1 = base1 + jnp.einsum("ecr,efr->ecf", tv, u)
    h = act(h1)
    if "w3" in c:
        if base3 is None:
            base3 = jnp.einsum("ecd,df->ecf", xg, c["w3"])
        tv3 = jnp.einsum("ecd,erd->ecr", xg, v["w3"])
        h = h * (base3 + jnp.einsum("ecr,efr->ecf", tv3, u))
    h = hint(h, ("experts", "expert_cap", "expert_mlp"))
    y = jnp.einsum("ecf,fd->ecd", h, c["w2"])
    t2 = jnp.einsum("ecf,efr->ecr", h, u)
    return y + jnp.einsum("ecr,erd->ecd", t2, v["w2"])


def _fused_kernel_expert_ffn(params, xg: jnp.ndarray, activation: str) -> jnp.ndarray:
    """Restore-free path on the grouped Pallas kernel (DESIGN.md §4.2).

    Identical math to :func:`_fused_expert_ffn`, but each segment's
    base + low-rank matmul pair runs as ONE ``pallas_call`` over the whole
    dispatched bank instead of separate einsums — the center segment is
    never re-read per expert and the restored bank is never materialized.

    On an int8 store the dequant-fused kernel variant streams the factors
    as int8 and folds the per-channel scales into the f32 accumulators
    (DESIGN.md §9) — the store is never dequantized in HBM.
    """
    act = activation_fn(activation)
    c, u, v = params["center"], params["u"], params["v"]
    ut = jnp.swapaxes(u, 1, 2)  # [E, r, f] — shared by the w1/w3 segments
    if is_quantized_store(params):
        from ..kernels import grouped_lowrank_matmul_q8

        cs, us, vs = (params["center_scale"], params["u_scale"],
                      params["v_scale"])
        h = act(grouped_lowrank_matmul_q8(
            xg, c["w1"], cs["w1"], jnp.swapaxes(v["w1"], 1, 2), ut,
            vs["w1"] * us))
        if "w3" in c:
            h = h * grouped_lowrank_matmul_q8(
                xg, c["w3"], cs["w3"], jnp.swapaxes(v["w3"], 1, 2), ut,
                vs["w3"] * us)
        h = hint(h, ("experts", "expert_cap", "expert_mlp"))
        y = grouped_lowrank_matmul_q8(h, c["w2"], cs["w2"], u, v["w2"],
                                      us * vs["w2"])
        return hint(y, ("experts", "expert_cap", "embed"))
    from ..kernels import grouped_lowrank_matmul

    h = act(grouped_lowrank_matmul(xg, c["w1"], jnp.swapaxes(v["w1"], 1, 2), ut))
    if "w3" in c:
        h = h * grouped_lowrank_matmul(
            xg, c["w3"], jnp.swapaxes(v["w3"], 1, 2), ut
        )
    h = hint(h, ("experts", "expert_cap", "expert_mlp"))
    y = grouped_lowrank_matmul(h, c["w2"], u, v["w2"])
    return hint(y, ("experts", "expert_cap", "embed"))


def center_only_ffn(params: Dict, x2d: jnp.ndarray, gates: jnp.ndarray,
                    activation: str) -> jnp.ndarray:
    """Barycenter-drafter math (launch/spec.py, DESIGN.md §12).

    Every routed expert is approximated by the shared center, so the
    top-k mixture collapses to ONE dense FFN pass scaled by the token's
    total gate mass: ``y = (sum_k g_k) * FFN_center(x)`` — no u/v
    gathers, no capacity dispatch, no per-expert compute. With normalized
    gates the scale is exactly 1; routing still runs because the gate
    mass (and the aux metrics) depend on it. An int8 store dequantizes
    the center in-graph (the factors are never touched).
    """
    act = activation_fn(activation)
    c = params["center"]
    if "center_scale" in params:
        from ..core.quant import dequantize_int8

        c = {name: dequantize_int8(w, params["center_scale"][name], -2)
             for name, w in c.items()}
    h = act(jnp.einsum("td,df->tf", x2d, c["w1"]))
    if "w3" in c:
        h = h * jnp.einsum("td,df->tf", x2d, c["w3"])
    h = hint(h, ("batch", "expert_mlp"))
    y = jnp.einsum("tf,fd->td", h, c["w2"])
    return y * gates.sum(-1, keepdims=True).astype(y.dtype)


def svd_store_expert_ffn(store, xg: jnp.ndarray, activation: str,
                         mode: str) -> jnp.ndarray:
    """Run the restore-free expert math on an (optionally int8) SVD store.

    One dispatch point for the GSPMD layer and the EP shard_map region:
    ``fused_kernel`` goes to the grouped Pallas kernel (dequant-fused on
    int8 stores); ``fused`` runs the einsum path, dequantizing an int8
    store in-graph first (the einsums have no register-level dequant to
    fuse into).
    """
    if mode == "fused_kernel":
        return _fused_kernel_expert_ffn(store, xg, activation)
    if is_quantized_store(store):
        store = {**store, **dequantize_store(store)}
    return _fused_expert_ffn(store, xg, activation)


# ---------------------------------------------------------------------------
# Full layer
# ---------------------------------------------------------------------------


def moe_layer(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # [B, S, d]
    cfg: ModelConfig,
    apply_mode: Optional[str] = None,
    capacity_per_row: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Run one MoE layer. ``params`` holds either a dense bank or a ResMoE
    compressed store (decided by key presence); ``apply_mode`` overrides
    cfg.resmoe.apply_mode
    ("restored" | "fused" | "fused_shared" | "fused_kernel" |
    "fused_token" | "center_only").

    ``capacity_per_row`` switches the capacity-padded dispatch to
    per-batch-row expert capacity (``expert_capacity(S, m)`` instead of
    ``expert_capacity(B*S, m)``, each row dispatched independently via
    :func:`make_dispatch_per_row`) so a batched same-length prefill drops
    exactly the tokens each B=1 prefill would drop — the batched
    prefill-insert path of the overlapped serving engine (DESIGN.md §13).
    It declines the EP shard_map layer and the auto token-path crossover
    (both reason about the GLOBAL token count); an explicit
    ``apply_mode="fused_token"`` still wins — that path is capacity-free
    per token, so per-row capacity is vacuous there.

    SVD stores with a restore-free mode and a decode-sized token batch
    (``token_path_applicable``) skip the capacity-padded dispatch and run
    the ragged per-token kernel instead (DESIGN.md §4.4);
    ``apply_mode="fused_token"`` forces that path at any batch size.

    Under a sharding-rules context with a divisible 'model' axis, the dense
    path AND the ResMoE-SVD compressed store (restore-free modes ``fused``
    and ``fused_kernel``) switch to the explicit shard_map expert-parallel
    layer (moe_ep.py) — one psum per layer instead of GSPMD's resharding
    chain, with the shared center replicated and the per-expert low-rank
    factors sharded over 'model' (DESIGN.md §6).
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    x2d = hint(x.reshape(t, d), ("batch", None))

    compressed = "center" in params
    mode = apply_mode or cfg.resmoe.apply_mode
    per_row = capacity_per_row and b > 1

    if mode == "center_only" and not compressed:
        # checked BEFORE the EP gate: a dense bank under a mesh would
        # otherwise sail through ep_moe_layer (which ignores apply_mode
        # for dense banks) instead of failing loudly
        raise ValueError(
            "apply_mode='center_only' needs a compressed store — the "
            "shared barycenter center IS the draft model; a dense "
            "expert bank has no center to draft from")

    from ..sharding import current_rules
    from .moe_ep import ep_applicable, ep_moe_layer

    rules = current_rules()
    if not per_row and ep_applicable(params, cfg, rules, num_tokens=t,
                                     apply_mode=mode):
        y2d, aux = ep_moe_layer(params, x2d, cfg, rules, apply_mode=mode)
        return y2d.reshape(b, s, d).astype(x.dtype), aux

    if compressed and mode == "fused_token" and "u" not in params:
        raise ValueError(
            "apply_mode='fused_token' needs an SVD store (center/u/v); "
            "dense-delta (up/block) stores only support 'restored'"
        )

    expert_ids, gates, aux = route(params, x2d, m)

    if mode == "center_only":
        # barycenter drafter (launch/spec.py, DESIGN.md §12): the whole
        # bank collapses to the shared center; the per-expert factors are
        # never read. The EP gate above already declined (center_only is
        # not in _EP_COMPRESSED_MODES) — under a mesh the center is
        # replicated, so the GSPMD path here is exactly right.
        y2d = center_only_ffn(params, x2d, gates, cfg.activation)
        y2d = hint(y2d, ("batch", None))
        if "shared" in params:
            y2d = y2d + ffn(params["shared"], x2d, cfg.activation)
        if "dense" in params:
            y2d = y2d + ffn(params["dense"], x2d, cfg.activation)
        return y2d.reshape(b, s, d).astype(x.dtype), aux

    # Trimmed store (core/plan.py): u/v (+ scales) are compacted to the
    # kept experts and ``expert_map`` [E_orig] sends kept ids to compact
    # indices, dropped ids to -1. Routing is untouched — a token whose
    # expert was dropped keeps its gate mass but resolves to the shared
    # barycenter center (free: the center is resident for the drafter,
    # DESIGN.md §12). Dropped (token, expert) pairs get ZERO gates on the
    # kept-expert paths (every path multiplies by the gate, so their kept
    # contribution is exactly 0.0) and their original gates feed one
    # center_only_ffn pass — a fully-dropped token is therefore bitwise
    # the center_only output. ``raw_store`` is captured BEFORE the int8
    # dequant merge below: the merged dict still carries center_scale, and
    # center_only_ffn dequantizes for itself.
    trimmed = compressed and "expert_map" in params
    if trimmed:
        raw_store = params
        e_kept = params["u"].shape[0]
        cids = jnp.take(params["expert_map"], expert_ids, axis=0)
        dropped = cids < 0
        gates_dropped = jnp.where(dropped, gates, jnp.zeros_like(gates))
        gates = jnp.where(dropped, jnp.zeros_like(gates), gates)

    if (compressed and token_path_applicable(params, m, mode, t, rules=rules)
            and (mode == "fused_token" or not per_row)):
        # ragged capacity-free decode path: no [E, C, d] buffer, no
        # capacity drops, per-token gather of the low-rank factors
        if trimmed:
            # dropped pairs gather compact expert 0 with a zero gate — the
            # kernel multiplies every pair by its gate, so the arbitrary
            # gather target contributes exactly 0
            expert_ids = jnp.where(dropped, 0, cids)
        if is_quantized_store(params):
            from ..kernels import token_lowrank_moe_q8

            y2d = token_lowrank_moe_q8(
                x2d, expert_ids, gates, params["center"],
                params["center_scale"], params["u"], params["u_scale"],
                params["v"], params["v_scale"],
                activation=cfg.activation, out_dtype=x2d.dtype,
            )
        else:
            from ..kernels import token_lowrank_moe

            y2d = token_lowrank_moe(
                x2d, expert_ids, gates, params["center"], params["u"],
                params["v"], activation=cfg.activation, out_dtype=x2d.dtype,
            )
        if trimmed:
            y2d = y2d + center_only_ffn(raw_store, x2d, gates_dropped,
                                        cfg.activation).astype(y2d.dtype)
        y2d = hint(y2d, ("batch", None))
        if "shared" in params:
            y2d = y2d + ffn(params["shared"], x2d, cfg.activation)
        if "dense" in params:
            y2d = y2d + ffn(params["dense"], x2d, cfg.activation)
        return y2d.reshape(b, s, d).astype(x.dtype), aux

    if compressed and is_quantized_store(params) and mode != "fused_kernel":
        # non-kernel modes dequantize the int8 store in-graph (restored/
        # fused/fused_shared have no register-level dequant to fuse into);
        # fused_kernel consumes the int8 factors directly (DESIGN.md §9)
        params = {**params, **dequantize_store(params)}

    # a trimmed store dispatches over one extra SENTINEL group that all
    # dropped (token, expert) pairs land in; its output is hard zero (and
    # its gates already are), so the sentinel never contributes
    n_groups = (e_kept + 1) if trimmed else m.num_experts

    if per_row:
        # per-row capacity: each batch row drops exactly what its B=1
        # dispatch would; the buffer's capacity axis widens to B*C
        row_cap = expert_capacity(s, m)
        token_idx, dest, keep, sort_idx = make_dispatch_per_row(
            jnp.where(dropped, e_kept, cids) if trimmed else expert_ids,
            b, s, n_groups, row_cap)
        capacity = b * row_cap
    else:
        capacity = expert_capacity(t, m)
        token_idx, dest, keep, sort_idx = make_dispatch(
            jnp.where(dropped, e_kept, cids) if trimmed else expert_ids,
            n_groups, capacity)
    gates_flat = gates.reshape(-1)

    def run_groups(fn, *streams):
        """Dispatch the streams and run the expert math on the kept groups;
        a trimmed store's sentinel group is re-appended as exact zeros."""
        gs = [dispatch_tokens(z, token_idx, dest, keep, n_groups, capacity)
              for z in streams]
        if not trimmed:
            return fn(*gs)
        yg_k = fn(*(g[:-1] for g in gs))
        pad = jnp.zeros((1,) + yg_k.shape[1:], yg_k.dtype)
        return jnp.concatenate([yg_k, pad], axis=0)

    if not compressed:
        yg = run_groups(
            lambda xg: _dense_expert_ffn(params, xg, cfg.activation), x2d)
    elif mode == "restored" or "delta" in params:
        bank = _restored_bank(params)
        yg = run_groups(
            lambda xg: _dense_expert_ffn(bank, xg, cfg.activation), x2d)
    elif mode == "fused":
        yg = run_groups(
            lambda xg: _fused_expert_ffn(params, xg, cfg.activation), x2d)
    elif mode == "fused_kernel":
        yg = run_groups(
            lambda xg: _fused_kernel_expert_ffn(params, xg, cfg.activation),
            x2d)
    elif mode == "fused_shared":
        # center products computed ONCE per token (expert-independent)
        c = params["center"]
        b1 = jnp.einsum("td,df->tf", x2d, c["w1"])
        b3 = jnp.einsum("td,df->tf", x2d, c["w3"]) if "w3" in c else None
        if b3 is not None:
            yg = run_groups(
                lambda xg, b1g, b3g: _fused_expert_ffn(
                    params, xg, cfg.activation, base1=b1g, base3=b3g),
                x2d, b1, b3)
        else:
            yg = run_groups(
                lambda xg, b1g: _fused_expert_ffn(
                    params, xg, cfg.activation, base1=b1g),
                x2d, b1)
    else:
        raise ValueError(f"unknown apply mode {mode}")

    y2d = combine_tokens(yg, gates_flat, token_idx, dest, keep, t, sort_idx)

    if trimmed:
        y2d = y2d + center_only_ffn(raw_store, x2d, gates_dropped,
                                    cfg.activation).astype(y2d.dtype)

    if "shared" in params:
        y2d = y2d + ffn(params["shared"], x2d, cfg.activation)
    if "dense" in params:
        y2d = y2d + ffn(params["dense"], x2d, cfg.activation)
    # compressed stores may carry a wider dtype; keep the stream dtype stable
    return y2d.reshape(b, s, d).astype(x.dtype), aux
