"""Attention mixers: GQA/MQA (w/ sliding window), DeepSeek MLA, KV caches.

Conventions:
  * activations x: [B, S, d_model]
  * q/k/v: [B, S, H, D]
  * caches are per-layer dicts of arrays; the transformer scan stacks them
    with a leading layer axis.
  * ``window``: scalar (traced ok) — causal sliding-window size; pass a huge
    value (>= seq) for global attention. This keeps local/global layer mixes
    (gemma3) scannable with a per-layer window array.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import LogicalParam, hint
from .layers import apply_rope, dense_param, init_rms_norm, rms_norm

Cache = Dict[str, jnp.ndarray]

GLOBAL_WINDOW = 1 << 30  # sentinel: effectively unbounded causal attention


# ---------------------------------------------------------------------------
# Paged KV cache primitives (launch/paging.py holds the host-side allocator)
# ---------------------------------------------------------------------------
#
# A paged cache stores per-layer KV in a [num_pages, page_size, ...] pool
# shared by every decode slot; a [B, max_pages] block table (physical page
# per logical page, -1 = unallocated) threads through the cache dict under
# the "block_table" key, which is also how the attention mixers detect the
# paged layout. Unallocated/foreign pages are excluded two ways: the block
# table gives a per-page validity mask, and freed pages get their ``pos``
# rows reset to -GLOBAL_WINDOW (the same staleness sentinel the ring cache
# uses), so a reused page can never leak another request's positions into
# the causal mask. Writes to unmapped logical pages (free slots decoding
# padding tokens) resolve to an out-of-range flat index and are dropped.


def paged_update(pool: jnp.ndarray, new: jnp.ndarray, block_table: jnp.ndarray,
                 positions: jnp.ndarray) -> jnp.ndarray:
    """Scatter per-token values [B, S, ...] into a [P, page_size, ...] pool.

    ``positions`` [B, S] are absolute; the block table maps their logical
    page to a physical one. Entries whose logical page is unmapped (-1)
    flatten to an out-of-bounds index and are dropped.
    """
    p, ps = pool.shape[:2]
    b, s = positions.shape
    phys = jnp.take_along_axis(block_table, positions // ps, axis=1)  # [B, S]
    flat = jnp.where(phys >= 0, phys * ps + positions % ps, p * ps)
    return (
        pool.reshape((p * ps,) + pool.shape[2:])
        .at[flat.reshape(-1)]
        .set(new.astype(pool.dtype).reshape((b * s,) + pool.shape[2:]),
             mode="drop")
        .reshape(pool.shape)
    )


def paged_gather(pool: jnp.ndarray, block_table: jnp.ndarray) -> jnp.ndarray:
    """Per-slot contiguous view [B, max_pages*page_size, ...] of a pool.

    The jnp stand-in for a paged-attention kernel (mirrors _sdpa standing in
    for flash): unmapped entries gather page 0 and rely on the caller's
    validity mask + the -GLOBAL_WINDOW position sentinel.
    """
    b, m = block_table.shape
    ps = pool.shape[1]
    out = pool[jnp.maximum(block_table, 0)]  # [B, M, ps, ...]
    return out.reshape((b, m * ps) + pool.shape[2:])


def paged_valid(block_table: jnp.ndarray, page_size: int) -> jnp.ndarray:
    """[B, max_pages*page_size] bool: token slots backed by an owned page."""
    return jnp.repeat(block_table >= 0, page_size, axis=1)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, dtype) -> Dict[str, LogicalParam]:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_param(ks[0], (d, hq, hd), ("embed", "heads", "head_dim"), dtype),
        "wk": dense_param(ks[1], (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": dense_param(ks[2], (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": dense_param(ks[3], (hq, hd, d), ("heads", "head_dim", "embed"), dtype,
                          fan_in=hq * hd),
    }
    if getattr(cfg, "qk_norm", False) or cfg.name.startswith("gemma3"):
        p["q_norm"] = init_rms_norm(hd)
        p["k_norm"] = init_rms_norm(hd)
    return p


_Q_CHUNK = 512  # query-block size for the chunked (flash-style) path


def _sdpa_block(qg, k, v, q_pos, k_pos, window, k_valid, softcap, dh):
    """One query block: qg [B, Tq, Hkv, G, D] vs full keys.

    bf16 operands + f32 accumulation (preferred_element_type): the MXU path;
    avoids materializing f32 copies of q/k in HBM.
    """
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(dh)
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    causal = k_pos[:, None, :] <= q_pos[:, :, None]  # [B, Tq, Tk]
    in_window = (q_pos[:, :, None] - k_pos[:, None, :]) < window
    mask = causal & in_window
    if k_valid is not None:
        mask = mask & k_valid[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    # shard the KEY dim of the score block over 'model': q/k/v enter with the
    # flat head dim sharded, which is NOT representable on the (hkv, g)
    # split — hinting the head dims forced SPMD into involuntary full
    # rematerialization (measured: +15% bytes, 14x collectives on llama3
    # train). Key-dim sharding keeps softmax stats as small all-reduces.
    scores = hint(scores, ("batch", None, None, None, "cache_seq"))
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


def _sdpa(
    q: jnp.ndarray,  # [B, Tq, Hq, D]
    k: jnp.ndarray,  # [B, Tk, Hkv, D]
    v: jnp.ndarray,  # [B, Tk, Hkv, D]
    q_pos: jnp.ndarray,  # [B, Tq]
    k_pos: jnp.ndarray,  # [B, Tk] (or [1, Tk])
    window,
    k_valid: Optional[jnp.ndarray] = None,  # [B, Tk] bool
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Causal/windowed attention.

    Long query sequences run block-wise (lax.scan over query chunks) so the
    peak score buffer is [B, H, chunk, Tk] instead of [B, H, Tq, Tk] — the
    jnp stand-in for a flash kernel; masks/results are identical.
    """
    b, tq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, tq, hkv, g, dh)
    if tq <= _Q_CHUNK or tq % _Q_CHUNK:
        out = _sdpa_block(qg, k, v, q_pos, k_pos, window, k_valid, softcap, dh)
        return out.reshape(b, tq, hq, dh)

    nq = tq // _Q_CHUNK
    qs = qg.reshape(b, nq, _Q_CHUNK, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    ps = q_pos.reshape(b, nq, _Q_CHUNK).transpose(1, 0, 2)

    def body(_, xs):
        qc, pc = xs
        oc = _sdpa_block(qc, k, v, pc, k_pos, window, k_valid, softcap, dh)
        return 0, oc

    _, outs = jax.lax.scan(body, 0, (qs, ps))  # [nq, B, cq, Hkv, G, D]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, tq, hq, dh)
    return out


def gqa_attention(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # [B, S, d]
    positions: jnp.ndarray,  # [B, S]
    cfg: ModelConfig,
    window=GLOBAL_WINDOW,
    rope_theta=None,
    cache: Optional[Cache] = None,
    norm_eps: float = 1e-6,
    softcap: float = 0.0,
) -> Tuple[jnp.ndarray, Optional[Cache]]:
    """Full-sequence (train/prefill) or cached decode attention.

    If ``cache`` is provided, ``x`` holds the new tokens (usually S=1) and
    ``positions`` their positions; the cache is updated at those positions
    and attention runs against the whole cache.
    """
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"], norm_eps)
        k = rms_norm(k, params["k_norm"], norm_eps)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    q = hint(q, ("batch", "seq", "heads", None))

    if cache is None:
        out = _sdpa(q, k, v, positions, positions, window, softcap=softcap)
        new_cache = None
    elif "block_table" in cache:
        # Paged cache: shared [P, ps, Hkv, D] pools + per-slot block tables.
        # Stale offsets carry pos = -GLOBAL_WINDOW (reset on free) and
        # foreign pages are cut by the validity mask, so the gathered view
        # attends over exactly the positions the ring cache would — the
        # masked columns contribute exact zeros, keeping the two layouts
        # bitwise-identical (tests/test_serve.py differential suite).
        bt = cache["block_table"]
        ps = cache["pos"].shape[1]
        ck = paged_update(cache["k"], k, bt, positions)
        cv = paged_update(cache["v"], v, bt, positions)
        cpos = paged_update(cache["pos"], positions, bt, positions)
        out = _sdpa(
            q, paged_gather(ck, bt), paged_gather(cv, bt), positions,
            paged_gather(cpos, bt), window,
            k_valid=paged_valid(bt, ps), softcap=softcap,
        )
        new_cache = {"k": ck, "v": cv, "pos": cpos, "block_table": bt}
    else:
        # Ring-buffer cache: slot = position % cache_len. Absolute positions
        # are stored alongside so causal/window masks and slot-staleness fall
        # out of the same comparison (fresh slots init to -GLOBAL_WINDOW).
        ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
        b = x.shape[0]
        s_cache = ck.shape[1]
        bidx = jnp.arange(b)[:, None]
        idx = positions % s_cache
        ck = ck.at[bidx, idx].set(k.astype(ck.dtype))
        cv = cv.at[bidx, idx].set(v.astype(cv.dtype))
        cpos = cpos.at[bidx, idx].set(positions.astype(cpos.dtype))
        out = _sdpa(q, ck, cv, positions, cpos, window, softcap=softcap)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, new_cache


def init_gqa_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Cache:
    """Single-layer KV cache (axes tagged for the sharding layer)."""
    shape = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": LogicalParam(jnp.zeros(shape, dtype), ("batch", "cache_seq", "kv_heads", None)),
        "v": LogicalParam(jnp.zeros(shape, dtype), ("batch", "cache_seq", "kv_heads", None)),
        "pos": LogicalParam(
            jnp.full((batch, max_seq), -GLOBAL_WINDOW, jnp.int32), ("batch", "cache_seq")
        ),
    }


def init_gqa_paged_cache(cfg: ModelConfig, batch: int, num_pages: int,
                         page_size: int, max_pages: int, dtype) -> Cache:
    """Single-layer paged KV cache: shared page pool + per-slot block table.

    Pool ``pos`` starts at the -GLOBAL_WINDOW staleness sentinel; block
    tables start fully unmapped (-1). ``pages`` is replicated under the
    default sharding rules (pages interleave live requests, so there is no
    batch-dim sharding to inherit — a sequence-sharded paged pool would
    need a paged-attention kernel first).
    """
    shape = (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": LogicalParam(jnp.zeros(shape, dtype), ("pages", None, "kv_heads", None)),
        "v": LogicalParam(jnp.zeros(shape, dtype), ("pages", None, "kv_heads", None)),
        "pos": LogicalParam(
            jnp.full((num_pages, page_size), -GLOBAL_WINDOW, jnp.int32),
            ("pages", None),
        ),
        # "page_table" marks the block-table leaf for the serving layer's
        # host-side surgery (sync/merge/reset) — recurrent state leaves
        # share the "batch" axis, so "batch" alone no longer identifies it
        "block_table": LogicalParam(
            jnp.full((batch, max_pages), -1, jnp.int32),
            ("batch", "page_table")
        ),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-v3 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, dtype) -> Dict[str, LogicalParam]:
    d, h = cfg.d_model, cfg.num_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    p: Dict[str, LogicalParam] = {}
    if rq > 0:
        p["wq_a"] = dense_param(ks[0], (d, rq), ("embed", "q_lora"), dtype)
        p["q_norm"] = init_rms_norm(rq)
        p["wq_b"] = dense_param(ks[1], (rq, h, dn + dr), ("q_lora", "heads", None), dtype,
                                fan_in=rq)
    else:
        p["wq"] = dense_param(ks[1], (d, h, dn + dr), ("embed", "heads", None), dtype)
    p["wkv_a"] = dense_param(ks[2], (d, rkv + dr), ("embed", "kv_lora"), dtype)
    p["kv_norm"] = init_rms_norm(rkv)
    p["wk_b"] = dense_param(ks[3], (rkv, h, dn), ("kv_lora", "heads", None), dtype, fan_in=rkv)
    p["wv_b"] = dense_param(ks[4], (rkv, h, dv), ("kv_lora", "heads", None), dtype, fan_in=rkv)
    p["wo"] = dense_param(ks[5], (h, dv, d), ("heads", None, "embed"), dtype, fan_in=h * dv)
    return p


def _mla_qkr(params, x, positions, cfg):
    """Project to q (nope+rope), kv latent, shared rope key."""
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if "wq_a" in params:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["wq_a"]), params["q_norm"],
                      cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv = rms_norm(kv[..., : cfg.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank :][:, :, None, :]  # [B,S,1,dr]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Cache] = None,
    window=GLOBAL_WINDOW,
) -> Tuple[jnp.ndarray, Optional[Cache]]:
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, x, positions, cfg)
    scale = 1.0 / math.sqrt(dn + dr)

    if cache is None:
        # expanded form: materialize per-head k/v from the latent
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"])
        v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"])
        k_rope_b = jnp.broadcast_to(k_rope, (b, s, dr))

        def block(qn, qr, qpos):
            scores = (
                jnp.einsum("bqhe,bkhe->bhqk", qn.astype(jnp.float32),
                           k_nope.astype(jnp.float32))
                + jnp.einsum("bqhe,bke->bhqk", qr.astype(jnp.float32),
                             k_rope_b.astype(jnp.float32))
            ) * scale
            causal = (positions[:, None, :] <= qpos[:, :, None]) & (
                (qpos[:, :, None] - positions[:, None, :]) < window
            )
            scores = jnp.where(causal[:, None, :, :], scores, -1e30)
            scores = hint(scores, ("batch", "heads", None, "cache_seq"))
            probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
            return jnp.einsum("bhqk,bkhd->bqhd", probs, v)

        if s <= _Q_CHUNK or s % _Q_CHUNK:
            out = block(q_nope, q_rope, positions)
        else:
            nq = s // _Q_CHUNK

            def chunk(x):
                return x.reshape((b, nq, _Q_CHUNK) + x.shape[2:]).transpose(
                    (1, 0, 2) + tuple(range(3, x.ndim + 1))
                )

            def body(_, xs):
                qn, qr, qp = xs
                return 0, block(qn, qr, qp)

            _, outs = jax.lax.scan(
                body, 0, (chunk(q_nope), chunk(q_rope), chunk(positions))
            )  # [nq, B, cq, H, dv]
            out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)
        new_cache = None
    else:
        # absorbed decode form: attend directly over the latent cache.
        if "block_table" in cache:
            bt = cache["block_table"]
            ps = cache["pos"].shape[1]
            pc = paged_update(cache["c_kv"], c_kv, bt, positions)
            pr = paged_update(cache["k_rope"], k_rope, bt, positions)
            ppos = paged_update(cache["pos"], positions, bt, positions)
            cc, cr, cpos = (paged_gather(pc, bt), paged_gather(pr, bt),
                            paged_gather(ppos, bt))
            k_valid = paged_valid(bt, ps)
            new_cache = {"c_kv": pc, "k_rope": pr, "pos": ppos,
                         "block_table": bt}
        else:
            cc, cr, cpos = cache["c_kv"], cache["k_rope"], cache["pos"]
            bidx = jnp.arange(b)[:, None]
            s_cache = cc.shape[1]
            idx = positions % s_cache
            cc = cc.at[bidx, idx].set(c_kv.astype(cc.dtype))
            cr = cr.at[bidx, idx].set(k_rope.astype(cr.dtype))
            cpos = cpos.at[bidx, idx].set(positions.astype(cpos.dtype))
            k_valid = None
            new_cache = {"c_kv": cc, "k_rope": cr, "pos": cpos}
        # absorb wk_b into q: q_lat [B,S,H,rkv]
        q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, params["wk_b"])
        valid = (cpos[:, None, :] <= positions[:, :, None]) & (
            (positions[:, :, None] - cpos[:, None, :]) < window
        )  # [B, Tq, S_cache]
        if k_valid is not None:
            valid = valid & k_valid[:, None, :]
        scores = (
            jnp.einsum("bqhr,bkr->bhqk", q_lat.astype(jnp.float32), cc.astype(jnp.float32))
            + jnp.einsum("bqhe,bke->bhqk", q_rope.astype(jnp.float32),
                         cr.astype(jnp.float32))
        ) * scale
        scores = jnp.where(valid[:, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhqk,bkr->bqhr", probs, cc.astype(jnp.float32))
        out = jnp.einsum("bqhr,rhd->bqhd", out_lat.astype(x.dtype), params["wv_b"])

    out = jnp.einsum("bqhd,hdo->bqo", out, params["wo"])
    return out, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Cache:
    return {
        "c_kv": LogicalParam(
            jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
            ("batch", "cache_seq", None),
        ),
        "k_rope": LogicalParam(
            jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
            ("batch", "cache_seq", None),
        ),
        "pos": LogicalParam(
            jnp.full((batch, max_seq), -GLOBAL_WINDOW, jnp.int32), ("batch", "cache_seq")
        ),
    }


def init_mla_paged_cache(cfg: ModelConfig, batch: int, num_pages: int,
                         page_size: int, max_pages: int, dtype) -> Cache:
    """Paged latent cache: same pool/block-table layout as the GQA variant."""
    return {
        "c_kv": LogicalParam(
            jnp.zeros((num_pages, page_size, cfg.kv_lora_rank), dtype),
            ("pages", None, None),
        ),
        "k_rope": LogicalParam(
            jnp.zeros((num_pages, page_size, cfg.qk_rope_head_dim), dtype),
            ("pages", None, None),
        ),
        "pos": LogicalParam(
            jnp.full((num_pages, page_size), -GLOBAL_WINDOW, jnp.int32),
            ("pages", None),
        ),
        "block_table": LogicalParam(
            jnp.full((batch, max_pages), -1, jnp.int32),
            ("batch", "page_table")
        ),
    }
