"""Model substrate: attention/recurrent mixers, FFN/MoE, transformer assembly."""
from .model import (
    Model,
    abstract_compressed_params,
    block_hidden_similarities,
    build_model,
    compress_model_params,
    iter_compressed_stores,
    iter_moe_banks,
    quantize_compressed_params,
)
from .transformer import build_plan, forward, init_cache, init_params, layer_specs, loss_fn

__all__ = [
    "Model",
    "abstract_compressed_params",
    "block_hidden_similarities",
    "build_model",
    "compress_model_params",
    "iter_compressed_stores",
    "iter_moe_banks",
    "quantize_compressed_params",
    "build_plan",
    "forward",
    "init_cache",
    "init_params",
    "layer_specs",
    "loss_fn",
]
