"""Model substrate: attention/recurrent mixers, FFN/MoE, transformer assembly."""
from .model import Model, build_model, compress_model_params, iter_moe_banks
from .transformer import build_plan, forward, init_cache, init_params, layer_specs, loss_fn

__all__ = [
    "Model",
    "build_model",
    "compress_model_params",
    "iter_moe_banks",
    "build_plan",
    "forward",
    "init_cache",
    "init_params",
    "layer_specs",
    "loss_fn",
]
