"""Recurrent mixers: RG-LRU (RecurrentGemma/Griffin) and RWKV6 (Finch).

Both expose (full-sequence, state-carrying) and (single-step, decode) forms.
The RG-LRU linear recurrence uses ``jax.lax.associative_scan`` (log-depth,
TPU-friendly); RWKV6's matrix-valued state uses ``jax.lax.scan`` over time.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import LogicalParam, hint
from .layers import dense_param, init_rms_norm, rms_norm, zeros_param

State = Dict[str, jnp.ndarray]

_RGLRU_C = 8.0
_CONV_WIDTH = 4


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin)
# ---------------------------------------------------------------------------


def init_rglru_block(key, cfg: ModelConfig, dtype) -> Dict[str, LogicalParam]:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    p = {
        "w_gate": dense_param(ks[0], (d, w), ("embed", "lru"), dtype),
        "w_in": dense_param(ks[1], (d, w), ("embed", "lru"), dtype),
        "conv_w": dense_param(ks[2], (_CONV_WIDTH, w), (None, "lru"), dtype, fan_in=_CONV_WIDTH),
        "conv_b": zeros_param((w,), ("lru",), dtype),
        "w_a": dense_param(ks[3], (w, w), ("lru", "lru"), dtype, fan_in=w),
        "b_a": zeros_param((w,), ("lru",), dtype),
        "w_x": dense_param(ks[4], (w, w), ("lru", "lru"), dtype, fan_in=w),
        "b_x": zeros_param((w,), ("lru",), dtype),
        # Lambda init so that a = sigmoid(lam)^c lands in [0.9, 0.999]
        "lam": LogicalParam(
            jnp.asarray(
                jax.random.uniform(ks[5], (w,), jnp.float32, 0.3, 0.9)
            ),
            ("lru",),
        ),
        "w_out": dense_param(ks[6], (w, d), ("lru", "embed"), dtype, fan_in=w),
    }
    return p


def _causal_conv(z: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 carry: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv, width _CONV_WIDTH. carry: [B, W-1, C] history."""
    bsz, s, c = z.shape
    if carry is None:
        carry = jnp.zeros((bsz, _CONV_WIDTH - 1, c), z.dtype)
    zc = jnp.concatenate([carry, z], axis=1)
    out = jnp.zeros_like(z)
    for i in range(_CONV_WIDTH):
        out = out + zc[:, i : i + s, :] * w[i][None, None, :]
    new_carry = zc[:, -(_CONV_WIDTH - 1) :, :]
    return out + b[None, None, :], new_carry


def _rglru_coeffs(params, z: jnp.ndarray):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", z, params["w_a"]) + params["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", z, params["w_x"]) + params["b_x"])
    log_a = -_RGLRU_C * r.astype(jnp.float32) * jax.nn.softplus(params["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = (mult * (i.astype(jnp.float32) * z.astype(jnp.float32)))
    return a, b  # f32 [B,S,W] each


def rglru_block(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # [B, S, d]
    cfg: ModelConfig,
    state: Optional[State] = None,
) -> Tuple[jnp.ndarray, Optional[State]]:
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate"]), approximate=True)
    z = jnp.einsum("bsd,dw->bsw", x, params["w_in"])
    conv_carry = state["conv"] if state is not None else None
    z, new_conv = _causal_conv(z, params["conv_w"], params["conv_b"], conv_carry)
    a, b = _rglru_coeffs(params, z)

    if state is None:
        # h_t = a_t h_{t-1} + b_t  ->  associative scan over (a, b)
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_state = None
    else:
        h_prev = state["h"].astype(jnp.float32)  # [B, W]
        # sequential (decode may still have S>1 for short bursts)
        def step(hp, ab):
            at, bt = ab
            hn = at * hp + bt
            return hn, hn

        hT, hs = jax.lax.scan(step, h_prev, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
        h = hs.swapaxes(0, 1)
        new_state = {"h": hT, "conv": new_conv}
    out = (h.astype(x.dtype) * gate)
    return jnp.einsum("bsw,wd->bsd", out, params["w_out"]), new_state


def init_rglru_state(cfg: ModelConfig, batch: int) -> State:
    """Zeroed RG-LRU decode state: hidden vector + causal-conv taps.

    Doubles as the PAGED serving state (DESIGN.md §11): with
    ``batch = num_slots`` each row is one slot's fixed-size state slot
    (``launch/paging.py::RecurrentSlots``) — there is no sequence axis to
    page. All-zeros IS the fresh-sequence state, which is what lets the
    serving loop reset a slot by zeroing its rows at admit and restore a
    preempted request bitwise by recomputing the prefill scan."""
    w = cfg.lru_width or cfg.d_model
    return {
        "h": LogicalParam(jnp.zeros((batch, w), jnp.float32), ("batch", "lru")),
        "conv": LogicalParam(
            jnp.zeros((batch, _CONV_WIDTH - 1, w), jnp.bfloat16), ("batch", None, "lru")
        ),
    }


# ---------------------------------------------------------------------------
# RWKV6 (Finch) — data-dependent decay linear attention
# ---------------------------------------------------------------------------

_RWKV_HEAD = 64
_LORA_DIM = 64


def init_rwkv6_block(key, cfg: ModelConfig, dtype) -> Dict[str, LogicalParam]:
    d = cfg.d_model
    ks = jax.random.split(key, 16)
    def lin(i, shape, axes, fan=None):
        return dense_param(ks[i], shape, axes, dtype, fan_in=fan)

    p = {
        # token-shift mix coefficients (static part) for r,k,v,w,g
        "mu": zeros_param((5, d), (None, "embed"), jnp.float32),
        # data-dependent shift lora: tanh(x @ A) @ B -> per-target mix delta
        "maa_a": lin(0, (d, 5, _LORA_DIM // 2), ("embed", None, None)),
        "maa_b": lin(1, (5, _LORA_DIM // 2, d), (None, None, "embed"), fan=_LORA_DIM // 2),
        "wr": lin(2, (d, d), ("embed", "heads")),
        "wk": lin(3, (d, d), ("embed", "heads")),
        "wv": lin(4, (d, d), ("embed", "heads")),
        "wg": lin(5, (d, d), ("embed", "heads")),
        "wo": lin(6, (d, d), ("heads", "embed"), fan=d),
        # decay: w = exp(-exp(w0 + lora(xw)))
        "w0": LogicalParam(
            jnp.linspace(-6.0, -1.0, d, dtype=jnp.float32), ("embed",)
        ),
        "w_a": lin(7, (d, _LORA_DIM), ("embed", None)),
        "w_b": lin(8, (_LORA_DIM, d), (None, "embed"), fan=_LORA_DIM),
        "u": zeros_param((d,), ("embed",), jnp.float32),  # bonus
        "ln_x": init_rms_norm(d),  # per-head group norm approx
        # channel mix
        "cm_mu": zeros_param((2, d), (None, "embed"), jnp.float32),
        "cm_k": lin(9, (d, cfg.d_ff), ("embed", "mlp")),
        "cm_v": lin(10, (cfg.d_ff, d), ("mlp", "embed"), fan=cfg.d_ff),
        "cm_r": lin(11, (d, d), ("embed", "embed")),
    }
    return p


def _shift(x: jnp.ndarray, carry: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Previous-token sequence ([B,S,d]); carry [B,d] = last token of prev chunk."""
    if carry is None:
        prev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    else:
        prev = jnp.concatenate([carry[:, None, :], x[:, :-1]], axis=1)
    return prev, x[:, -1, :]


def _wkv6_scan(r, k, v, w, u, state):
    """r,k,v,w: [B,S,H,hd] (w = decay in (0,1)); state: [B,H,hd,hd].

    y_t[j] = sum_i r_i (S[i,j] + u_i k_i v_j);  S <- diag(w) S + k v^T.
    """
    def step(s, inp):
        rt, kt, vt, wt = inp  # [B,H,hd]
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, w))  # [S,B,H,hd]
    final, ys = jax.lax.scan(step, state, xs)
    return ys.swapaxes(0, 1), final  # [B,S,H,hd]


def rwkv6_time_mix(
    params, x: jnp.ndarray, cfg: ModelConfig, state: Optional[State]
) -> Tuple[jnp.ndarray, Optional[State]]:
    b, s, d = x.shape
    h = d // _RWKV_HEAD
    shift_carry = state["shift_att"] if state is not None else None
    prev, last = _shift(x, shift_carry)
    xx = prev - x
    # data-dependent lerp for the five targets
    mix = jnp.tanh(jnp.einsum("bsd,dnk->bsnk", x, params["maa_a"]))
    mix = jnp.einsum("bsnk,nkd->bsnd", mix, params["maa_b"])  # [B,S,5,d]
    mu = params["mu"][None, None]  # [1,1,5,d]
    xs = (x[:, :, None, :] + xx[:, :, None, :] * (mu + mix)).astype(x.dtype)
    xr, xk, xv, xw, xg = [xs[:, :, i, :] for i in range(5)]

    r = jnp.einsum("bsd,de->bse", xr, params["wr"]).reshape(b, s, h, _RWKV_HEAD)
    k = jnp.einsum("bsd,de->bse", xk, params["wk"]).reshape(b, s, h, _RWKV_HEAD)
    v = jnp.einsum("bsd,de->bse", xv, params["wv"]).reshape(b, s, h, _RWKV_HEAD)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["wg"]))
    logw = params["w0"][None, None] + jnp.einsum(
        "bsd,dk,ke->bse", jnp.tanh(xw.astype(jnp.float32)), params["w_a"].astype(jnp.float32),
        params["w_b"].astype(jnp.float32)
    )
    w = jnp.exp(-jnp.exp(logw)).reshape(b, s, h, _RWKV_HEAD)

    s0 = (
        state["wkv"].astype(jnp.float32)
        if state is not None
        else jnp.zeros((b, h, _RWKV_HEAD, _RWKV_HEAD), jnp.float32)
    )
    u = params["u"].reshape(h, _RWKV_HEAD).astype(jnp.float32)
    y, s_fin = _wkv6_scan(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w.astype(jnp.float32), u, s0
    )
    y = y.reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y, params["ln_x"], cfg.norm_eps) * g
    out = jnp.einsum("bsd,de->bse", y, params["wo"])
    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["wkv"] = s_fin
        new_state["shift_att"] = last
    return out, new_state


def rwkv6_channel_mix(
    params, x: jnp.ndarray, cfg: ModelConfig, state: Optional[State]
) -> Tuple[jnp.ndarray, Optional[State]]:
    shift_carry = state["shift_ffn"] if state is not None else None
    prev, last = _shift(x, shift_carry)
    xx = prev - x
    mu = params["cm_mu"][None, None]
    xk = (x + xx * mu[:, :, 0]).astype(x.dtype)
    xr = (x + xx * mu[:, :, 1]).astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, params["cm_k"])))
    kv = jnp.einsum("bsf,fd->bsd", k, params["cm_v"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["cm_r"])) * kv
    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["shift_ffn"] = last
    return out, new_state


def init_rwkv6_state(cfg: ModelConfig, batch: int) -> State:
    """Zeroed RWKV6 decode state: per-head wkv matrix + token-shift rows.

    Like :func:`init_rglru_state`, these arrays double as per-slot state
    slots under paged serving (DESIGN.md §11) — O(1) per sequence,
    all-zeros at a fresh sequence, recompute-restored after preemption."""
    d = cfg.d_model
    h = d // _RWKV_HEAD
    return {
        "wkv": LogicalParam(
            jnp.zeros((batch, h, _RWKV_HEAD, _RWKV_HEAD), jnp.float32),
            ("batch", "heads", None, None),
        ),
        "shift_att": LogicalParam(jnp.zeros((batch, d), jnp.bfloat16), ("batch", None)),
        "shift_ffn": LogicalParam(jnp.zeros((batch, d), jnp.bfloat16), ("batch", None)),
    }
