"""Expert-parallel MoE layer via shard_map (explicit collectives).

A perf audit of the GSPMD lowering (numbers now inlined in DESIGN.md §6)
showed the gather/scatter dispatch reshards token buffers ~10x more than
the minimal EP exchange. This layer makes every data movement explicit:

  * activations x2d [T, d]: sharded over the batch axes, REPLICATED over
    'model' — each model shard sees its data shard's tokens with full d;
  * experts: sharded over 'model' (E_loc = E/|model| per shard);
  * each shard locally dispatches ONLY the (token, expert) pairs whose
    expert it owns — zero communication for dispatch;
  * combine = one psum over 'model' of the [T_loc, d] partial outputs
    (shared experts / arctic's dense-residual branch are computed f-sharded
    inside the same region and folded into the SAME psum).

Two expert-weight layouts are supported (DESIGN.md §6):

  * dense bank {w1, (w3), w2}: each [E, ...] tensor sharded over 'model';
  * ResMoE-SVD compressed store {center, u, v}: the (small, shared)
    ``center`` segments are REPLICATED over 'model' while the per-expert
    low-rank factors ``u``/``v`` are sharded over 'model', and each shard
    runs the restore-free math (the ``fused`` einsums or the
    ``fused_kernel`` grouped Pallas path) on its local E_loc expert slice.
    ``restored``/``fused_shared`` and the dense-delta (up/block) stores
    keep the GSPMD path — they materialize global-bank or pre-dispatch
    quantities that defeat the local-slice schedule. The int8-quantized
    store (DESIGN.md §9) serves identically: the fp32 per-channel scales
    travel with their factors (center scales replicated, rank scales
    'model'-sharded) and each shard runs the dequant-fused kernel (or
    dequantizes its local slice in-graph under ``fused``).

Per-layer communication: exactly one [T_loc, d] all-reduce (+ the ZeRO-3
weight gather inserted by pjit when expert weights are also data-sharded
for capacity) — the minimal schedule for replicated-activation EP.

Used automatically by moe_layer when a rules context is active and the
expert count divides the 'model' axis; falls back to the GSPMD path
otherwise (small expert counts, no mesh).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..sharding import ShardingRules, axis_size, shard_map_unchecked, use_rules
from .layers import activation_fn


_EP_MIN_LOCAL_TOKENS = 2048  # below this, weight gathers dominate — GSPMD
                             # with the weight-stationary hints wins (decode)

# Compressed apply modes whose math runs unchanged on a local expert slice.
# "fused_token" is deliberately absent: it exists for decode-sized batches,
# which sit far below _EP_MIN_LOCAL_TOKENS anyway (DESIGN.md §4.4).
_EP_COMPRESSED_MODES = ("fused", "fused_kernel")


def _is_svd_store(params: Dict) -> bool:
    return "center" in params and "u" in params and "v" in params


def ep_applicable(params: Dict, cfg: ModelConfig, rules: Optional[ShardingRules],
                  num_tokens: Optional[int] = None,
                  apply_mode: Optional[str] = None) -> bool:
    if rules is None or cfg.moe is None:
        return False
    if _is_svd_store(params):
        # restore-free modes only: 'restored' materializes the global bank
        # and 'fused_shared' computes center products pre-dispatch — both
        # defeat the local-slice schedule (DESIGN.md §6).
        mode = apply_mode or cfg.resmoe.apply_mode
        if mode not in _EP_COMPRESSED_MODES:
            return False
        if "expert_map" in params:
            # trimmed store (core/plan.py): the compacted expert count is
            # not the routed expert count, so the even experts-per-shard
            # slicing (and _param_specs) does not apply — GSPMD path
            return False
    elif "w1" not in params:  # dense-delta (up/block) stores: GSPMD path
        return False
    mesh = rules.mesh
    if "model" not in mesh.axis_names:
        return False
    msize = axis_size(mesh, "model")
    m = cfg.moe
    if m.num_experts % msize or msize <= 1:
        return False
    # shared/dense branches are f-sharded over model inside the region
    f_sh = m.expert_d_ff * max(1, m.num_shared_experts)
    if m.num_shared_experts and f_sh % msize:
        return False
    if m.dense_residual and cfg.d_ff % msize:
        return False
    if num_tokens is not None:
        dp = 1
        for a in rules.batch_axes:
            dp *= axis_size(mesh, a)
        if num_tokens % dp:
            return False  # the region's P(batch, None) in_spec needs an
            # even token split (e.g. odd-length B=1 prefill) — GSPMD copes
        thr = (m.ep_min_local_tokens if m.ep_min_local_tokens is not None
               else _EP_MIN_LOCAL_TOKENS)
        if num_tokens // dp < thr:
            return False  # decode/small-batch: EP's per-layer weight
            # all-gather (ZeRO-3 over 'data') exceeds the activation
            # resharding of the GSPMD path (measured: deepseek decode
            # 0.10 -> 3.35 s collective) — see DESIGN.md §6.
    return True


def _param_specs(params: Dict, cfg: ModelConfig) -> Dict:
    """shard_map in_specs for the MoE param dict (weight layouts)."""
    specs: Dict = {}
    for k in params:
        if k in ("w1", "w3"):
            specs[k] = P("model", None, None)
        elif k == "w2":
            specs[k] = P("model", None, None)
        elif k == "center":
            # the shared barycenter segments are small — replicate them
            specs[k] = {name: P(None, None) for name in params[k]}
        elif k == "u":
            specs[k] = P("model", None, None)
        elif k == "v":
            specs[k] = {name: P("model", None, None) for name in params[k]}
        elif k == "center_scale":  # int8 store: fp32 per-channel scales
            specs[k] = {name: P(None) for name in params[k]}
        elif k == "u_scale":  # [E, r] — sharded with its factor
            specs[k] = P("model", None)
        elif k == "v_scale":
            specs[k] = {name: P("model", None) for name in params[k]}
        elif k == "router":
            specs[k] = P(None, None)
        elif k == "router_bias":
            specs[k] = P(None)
        elif k in ("shared", "dense"):
            sub = {"w1": P(None, "model"), "w2": P("model", None)}
            if "w3" in params[k]:
                sub["w3"] = P(None, "model")
            specs[k] = sub
    return specs


def ep_moe_layer(
    params: Dict[str, jnp.ndarray],
    x2d: jnp.ndarray,  # [T, d] (global)
    cfg: ModelConfig,
    rules: ShardingRules,
    apply_mode: Optional[str] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    from .moe import (
        combine_tokens,
        dispatch_tokens,
        expert_capacity,
        make_dispatch,
        route,
        svd_store_expert_ffn,
    )

    m = cfg.moe
    mesh = rules.mesh
    msize = axis_size(mesh, "model")
    e_loc = m.num_experts // msize
    compressed = _is_svd_store(params)
    mode = apply_mode or cfg.resmoe.apply_mode
    batch_axes = tuple(rules.batch_axes)
    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    t_global = x2d.shape[0]
    dp = 1
    for a in batch_axes:
        dp *= axis_size(mesh, a)
    t_loc = t_global // dp  # divisibility guaranteed by ep_applicable
    # per-LOCAL-expert capacity for the local token slice (already a
    # per-expert quantity — do NOT divide by the model-axis size)
    cap = expert_capacity(t_loc, m)

    def region(params, x_loc):
        # hints are no-ops inside shard_map (local arrays)
        with use_rules(None):
            expert_ids, gates, aux = route(
                {k: params[k] for k in ("router", "router_bias") if k in params},
                x_loc, m,
            )
            my_lo = jax.lax.axis_index("model") * e_loc
            local_ids = expert_ids - my_lo
            mine = (local_ids >= 0) & (local_ids < e_loc)
            # foreign pairs -> dummy expert e_loc (dropped by capacity mask)
            ids = jnp.where(mine, local_ids, e_loc).astype(jnp.int32)
            gates = jnp.where(mine, gates, 0.0)
            token_idx, dest, keep, sort_idx = make_dispatch(ids, e_loc + 1, cap)
            xg = dispatch_tokens(x_loc, token_idx, dest, keep, e_loc + 1, cap)
            xg = xg[:e_loc]  # drop the dummy group

            act = activation_fn(cfg.activation)
            if compressed:
                # local slice of the store: u/v (and their rank scales on
                # an int8 store) are [E_loc, ...] here, center arrived
                # replicated (full [d, f] / [f, d])
                store = {k: params[k] for k in
                         ("center", "u", "v",
                          "center_scale", "u_scale", "v_scale")
                         if k in params}
                yg = svd_store_expert_ffn(store, xg, cfg.activation, mode)
            else:
                h = jnp.einsum("ecd,edf->ecf", xg, params["w1"])
                h = act(h)
                if "w3" in params:
                    h = h * jnp.einsum("ecd,edf->ecf", xg, params["w3"])
                yg = jnp.einsum("ecf,efd->ecd", h, params["w2"])
            yg = jnp.concatenate(
                [yg, jnp.zeros((1,) + yg.shape[1:], yg.dtype)], axis=0
            )  # restore dummy slot for combine indexing
            y_part = combine_tokens(
                yg, gates.reshape(-1), token_idx, dest, keep, x_loc.shape[0],
                sort_idx,
            )
            # f-sharded always-on branches fold into the same psum
            for name in ("shared", "dense"):
                if name in params:
                    w = params[name]
                    hh = jnp.einsum("td,df->tf", x_loc, w["w1"])
                    hh = act(hh)
                    if "w3" in w:
                        hh = hh * jnp.einsum("td,df->tf", x_loc, w["w3"])
                    y_part = y_part + jnp.einsum("tf,fd->td", hh, w["w2"])
            y = jax.lax.psum(y_part, "model")
            # aux identical across 'model'; average over the batch axes
            aux = jax.tree_util.tree_map(
                lambda a: jax.lax.pmean(a, batch_axes), aux
            )
            return y, aux

    in_specs = (_param_specs(params, cfg), P(bspec, None))
    out_specs = (P(bspec, None), P())
    y, aux = shard_map_unchecked(
        region, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )({k: v for k, v in params.items() if k in _param_specs(params, cfg)}, x2d)
    return y, aux
