"""Transformer assembly: layer plans, scan-over-layers, train/prefill/decode.

An architecture config compiles to a *layer plan*: a list of segments, each a
``(pattern, repeats)`` pair where ``pattern`` is a tuple of LayerSpecs (one
per slot).  Segments with ``repeats > 1`` are executed with ``jax.lax.scan``
over stacked parameters — compile time is O(#segments × pattern), not
O(num_layers), which is what makes the 126-layer dry-runs tractable.

Heterogeneous stacks (gemma3 local/global, recurrentgemma's rec-rec-attn
pattern, deepseek's dense prefix) are expressed by multi-slot patterns with
*static* per-slot specs, so every scanned leaf keeps a uniform shape.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import LogicalParam, hint, split_logical
from . import attention as attn
from . import recurrent as rec
from .ffn import ffn, init_ffn
from .layers import (
    cross_entropy_loss,
    dense_param,
    embed_tokens,
    init_embedding,
    init_rms_norm,
    logits_from_embedding,
    rms_norm,
)
from .moe import init_moe, moe_layer

PyTree = Any


# ---------------------------------------------------------------------------
# Layer plans
# ---------------------------------------------------------------------------


# Every mixer kind a LayerSpec can name. The serving layer builds one
# StatePage per kind family (launch/paging.py) and scripts/
# check_parity_matrix.py requires a `# PARITY: mixer/<kind>` differential
# serving test per entry — adding a kind here fails CI until both exist.
MIXER_KINDS = ("gqa", "mla", "rglru", "rwkv")


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str  # one of MIXER_KINDS
    ffn: str  # "ffn" | "moe" | "rwkv_cm"
    window: int = attn.GLOBAL_WINDOW
    rope_theta: float = 10000.0
    # Per-layer compression recipe (core/plan.py). None for all layers when
    # no plan is active — and also for layers whose recipe is the default,
    # so a trivial plan yields the exact segmentation (and scan stacking)
    # of plan=None. Non-None recipes split scanned segments only where they
    # differ, via the tuple equality build_plan already keys on.
    recipe: Optional[Any] = None


@dataclasses.dataclass(frozen=True)
class Segment:
    pattern: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


def layer_specs(cfg: ModelConfig) -> List[LayerSpec]:
    """Per-layer specs in execution order."""
    specs = []
    for i in range(cfg.num_layers):
        theta = cfg.rope_theta
        window = attn.GLOBAL_WINDOW
        mixer = "gqa"
        if cfg.attention_type == "mla":
            mixer = "mla"
        if cfg.recurrent_type == "rglru":
            period = cfg.recurrent_pattern or 3
            mixer = "gqa" if (i % period) == (period - 1) else "rglru"
            if mixer == "gqa":
                window = cfg.sliding_window or attn.GLOBAL_WINDOW
        elif cfg.recurrent_type == "rwkv6":
            mixer = "rwkv"
        if cfg.local_global_ratio > 0:
            period = cfg.local_global_ratio + 1
            is_global = (i % period) == (period - 1)
            if not is_global:
                window = cfg.sliding_window or 1024
                theta = 10000.0
            else:
                theta = cfg.rope_theta
        elif cfg.sliding_window > 0 and cfg.recurrent_type == "none":
            window = cfg.sliding_window

        f = "ffn"
        if cfg.recurrent_type == "rwkv6":
            f = "rwkv_cm"
        elif cfg.is_moe and i >= cfg.moe_first_layer and (
            (i - cfg.moe_first_layer) % cfg.moe_every == 0
        ):
            f = "moe"
        specs.append(LayerSpec(mixer=mixer, ffn=f, window=window, rope_theta=theta))

    plan = cfg.resmoe.plan
    if plan is not None:
        # ModelConfig.__post_init__ validated length / expert bounds /
        # moe-only recipes; here the plan reshapes the serving layer list:
        # dropped blocks vanish from params, caches, mixer_layout and the
        # segment plan all at once, and non-default recipes attach to their
        # LayerSpec so build_plan splits scanned runs exactly where the
        # store becomes heterogeneous.
        planned = []
        for spec, rec_ in zip(specs, plan.recipes):
            if rec_.drop_block:
                continue
            if not rec_.is_default:
                spec = dataclasses.replace(spec, recipe=rec_)
            planned.append(spec)
        specs = planned
    return specs


def mixer_layout(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """``(mixer, window)`` per layer in execution order — the input to
    :class:`~repro.launch.paging.ServingState` (host-side demand accounting
    without pulling model code into the allocator)."""
    return [(s.mixer, s.window) for s in layer_specs(cfg)]


def build_plan(cfg: ModelConfig) -> List[Segment]:
    """Greedy segmentation of the layer list into repeated patterns."""
    specs = layer_specs(cfg)
    # natural pattern period for this arch
    if cfg.recurrent_type == "rglru":
        period = cfg.recurrent_pattern or 3
    elif cfg.local_global_ratio > 0:
        period = cfg.local_global_ratio + 1
    else:
        period = 1

    segments: List[Segment] = []
    i = 0
    n = len(specs)
    while i < n:
        # a dense-prefix / pattern-change boundary: extend a uniform run
        pat = tuple(specs[i : i + period])
        if len(pat) < period:
            segments.append(Segment(tuple(specs[i:]), 1))
            break
        reps = 1
        j = i + period
        while j + period <= n and tuple(specs[j : j + period]) == pat:
            reps += 1
            j += period
        # handle a short tail that doesn't fit the pattern
        segments.append(Segment(pat, reps))
        i = j
    # merge trailing partial pattern handled above by the break
    return segments


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------


def _dtype_of(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def init_block(key, cfg: ModelConfig, spec: LayerSpec) -> Dict[str, Any]:
    dt = _dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": init_rms_norm(cfg.d_model), "norm2": init_rms_norm(cfg.d_model)}
    if spec.mixer == "gqa":
        p["attn"] = attn.init_gqa(k1, cfg, dt)
    elif spec.mixer == "mla":
        p["attn"] = attn.init_mla(k1, cfg, dt)
    elif spec.mixer == "rglru":
        p["attn"] = rec.init_rglru_block(k1, cfg, dt)
    elif spec.mixer == "rwkv":
        p["attn"] = rec.init_rwkv6_block(k1, cfg, dt)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == "ffn":
        p["ffn"] = init_ffn(k2, cfg.d_model, cfg.d_ff, cfg.glu, dt)
    elif spec.ffn == "moe":
        p["ffn"] = init_moe(k2, cfg, dt)
    elif spec.ffn == "rwkv_cm":
        p["ffn"] = {}  # rwkv channel-mix params live inside the mixer dict
    return p


def apply_block(
    params: Dict[str, Any],
    x: jnp.ndarray,
    spec: LayerSpec,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,
    apply_mode: Optional[str] = None,
    capacity_per_row: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict], Dict[str, jnp.ndarray]]:
    aux = {"load_balance_loss": jnp.zeros((), jnp.float32),
           "router_z_loss": jnp.zeros((), jnp.float32)}
    x = hint(x, ("batch", "seq", "embed_act"))
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    new_cache = cache
    if spec.mixer == "gqa":
        y, new_cache = attn.gqa_attention(
            params["attn"], h, positions, cfg, window=spec.window,
            rope_theta=spec.rope_theta, cache=cache, norm_eps=cfg.norm_eps,
        )
    elif spec.mixer == "mla":
        y, new_cache = attn.mla_attention(params["attn"], h, positions, cfg, cache=cache)
    elif spec.mixer == "rglru":
        y, new_cache = rec.rglru_block(params["attn"], h, cfg, state=cache)
    elif spec.mixer == "rwkv":
        y, new_cache = rec.rwkv6_time_mix(params["attn"], h, cfg, state=cache)
    else:
        raise ValueError(spec.mixer)
    x = x + y

    h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
    if spec.ffn == "ffn":
        y2 = ffn(params["ffn"], h2, cfg.activation)
    elif spec.ffn == "moe":
        y2, aux = moe_layer(params["ffn"], h2, cfg, apply_mode=apply_mode,
                            capacity_per_row=capacity_per_row)
    elif spec.ffn == "rwkv_cm":
        y2, new_cache = rec.rwkv6_channel_mix(params["attn"], h2, cfg, state=new_cache)
    else:
        raise ValueError(spec.ffn)
    return x + y2, new_cache, aux


def init_block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_seq: int):
    dt = _dtype_of(cfg)
    if spec.mixer == "gqa":
        window = spec.window
        s = min(max_seq, window + 8) if window < attn.GLOBAL_WINDOW else max_seq
        # round cache length to multiple of 128 for tiling friendliness
        s = min(max_seq, -(-s // 128) * 128)
        return attn.init_gqa_cache(cfg, batch, s, dt)
    if spec.mixer == "mla":
        return attn.init_mla_cache(cfg, batch, max_seq, dt)
    if spec.mixer == "rglru":
        return rec.init_rglru_state(cfg, batch)
    if spec.mixer == "rwkv":
        return rec.init_rwkv6_state(cfg, batch)
    raise ValueError(spec.mixer)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> PyTree:
    """Returns a tree of LogicalParam (use sharding.split_logical to strip)."""
    dt = _dtype_of(cfg)
    plan = build_plan(cfg)
    keys = jax.random.split(key, len(plan) + 3)
    params: Dict[str, Any] = {}
    if cfg.num_codebooks > 1:
        params["embed"] = LogicalParam(
            (jax.random.normal(keys[0], (cfg.num_codebooks, cfg.vocab_size, cfg.d_model),
                               jnp.float32) * cfg.d_model ** -0.5).astype(dt),
            ("codebooks", "vocab", "embed"),
        )
    else:
        params["embed"] = init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dt)

    segments = []
    for si, seg in enumerate(plan):
        skeys = jax.random.split(keys[si + 1], max(seg.repeats, 1) * len(seg.pattern))
        slots = []
        for slot_idx, spec in enumerate(seg.pattern):
            if seg.repeats > 1:
                reps = [
                    init_block(skeys[r * len(seg.pattern) + slot_idx], cfg, spec)
                    for r in range(seg.repeats)
                ]
                stacked = jax.tree_util.tree_map(
                    lambda *xs: LogicalParam(
                        jnp.stack([x.value for x in xs]),
                        ("layers",) + xs[0].axes,
                    ),
                    *reps,
                    is_leaf=lambda x: isinstance(x, LogicalParam),
                )
                slots.append(stacked)
            else:
                slots.append(init_block(skeys[slot_idx], cfg, spec))
        segments.append({"slots": slots})
    params["segments"] = segments
    params["final_norm"] = init_rms_norm(cfg.d_model)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            params["head"] = LogicalParam(
                jax.random.normal(
                    keys[-1], (cfg.num_codebooks, cfg.d_model, cfg.vocab_size), jnp.float32
                ).astype(dt)
                / (cfg.d_model ** 0.5),
                ("codebooks", "embed", "vocab"),
            )
        else:
            params["head"] = dense_param(
                keys[-1], (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt
            )
    return params


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> PyTree:
    plan = build_plan(cfg)
    out = []
    for seg in plan:
        slots = []
        for spec in seg.pattern:
            c = init_block_cache(cfg, spec, batch, max_seq)
            if seg.repeats > 1:
                c = jax.tree_util.tree_map(
                    lambda p: LogicalParam(
                        jnp.broadcast_to(p.value, (seg.repeats,) + p.value.shape).copy(),
                        ("layers",) + p.axes,
                    ),
                    c,
                    is_leaf=lambda x: isinstance(x, LogicalParam),
                )
            slots.append(c)
        out.append({"slots": slots})
    return out


def init_paged_cache(cfg: ModelConfig, batch: int, max_seq: int,
                     page_size: int, num_pages: int) -> PyTree:
    """Paged serving cache: per-layer page pools + per-slot block tables.

    Same segment/slot tree shape as :func:`init_cache`, so the forward pass
    is untouched — the attention mixers detect the paged layout by the
    ``block_table`` key. Every attention layer gets its own ``[num_pages,
    page_size, ...]`` pool but the SAME logical->physical mapping (one
    host-side PagePool drives every layer's table), mirroring vLLM's
    layout. Sliding-window layers keep full-length logical tables — the
    window is enforced by masking, not by ring reuse, so paged pools trade
    the ring cache's window-bounded storage for cross-request page sharing
    (the serving loop reclaims window-expired pages instead,
    launch/paging.py::TokenPages.reclaim).

    Recurrent mixers (rglru/rwkv) hold O(1) per-slot states with no
    sequence axis to page — each serving slot gets one fixed-size state
    slot, identical to the row cache's state rows (the StatePage split in
    DESIGN.md §11). ``batch`` is the slot count for those leaves, and the
    serving loop does row-granular surgery on them (zero at admit,
    row-insert after prefill).
    """
    plan = build_plan(cfg)
    max_pages = -(-max_seq // page_size)
    out = []
    for seg in plan:
        slots = []
        for spec in seg.pattern:
            dt = _dtype_of(cfg)
            if spec.mixer == "gqa":
                c = attn.init_gqa_paged_cache(
                    cfg, batch, num_pages, page_size, max_pages, dt)
            elif spec.mixer == "mla":
                c = attn.init_mla_paged_cache(
                    cfg, batch, num_pages, page_size, max_pages, dt)
            elif spec.mixer == "rglru":
                c = rec.init_rglru_state(cfg, batch)
            elif spec.mixer == "rwkv":
                c = rec.init_rwkv6_state(cfg, batch)
            else:
                raise ValueError(
                    f"unknown mixer {spec.mixer!r} (known: {MIXER_KINDS})")
            if seg.repeats > 1:
                c = jax.tree_util.tree_map(
                    lambda p: LogicalParam(
                        jnp.broadcast_to(p.value, (seg.repeats,) + p.value.shape).copy(),
                        ("layers",) + p.axes,
                    ),
                    c,
                    is_leaf=lambda x: isinstance(x, LogicalParam),
                )
            slots.append(c)
        out.append({"slots": slots})
    return out


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _remat_policy(name: str):
    if name == "none":
        return None
    if name == "nothing_saveable":
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    raise ValueError(name)


def _zero_aux():
    return {"load_balance_loss": jnp.zeros((), jnp.float32),
            "router_z_loss": jnp.zeros((), jnp.float32)}


def run_segments(
    params: PyTree,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    cache: Optional[PyTree] = None,
    remat: bool = False,
    apply_mode: Optional[str] = None,
    capacity_per_row: bool = False,
) -> Tuple[jnp.ndarray, Optional[PyTree], Dict[str, jnp.ndarray]]:
    plan = build_plan(cfg)
    aux_tot = _zero_aux()
    new_cache: Optional[list] = [] if cache is not None else None
    policy = _remat_policy(cfg.remat_policy) if remat else None

    for si, seg in enumerate(plan):
        seg_params = params["segments"][si]
        seg_cache = cache[si] if cache is not None else None

        def run_pattern(x, slot_params, slot_cache):
            aux_p = _zero_aux()
            outs = []
            for slot_idx, spec in enumerate(seg.pattern):
                c = slot_cache[slot_idx] if slot_cache is not None else None
                x, nc, aux = apply_block(
                    slot_params[slot_idx], x, spec, cfg, positions, cache=c,
                    apply_mode=apply_mode, capacity_per_row=capacity_per_row,
                )
                outs.append(nc)
                aux_p = jax.tree_util.tree_map(jnp.add, aux_p, aux)
            return x, outs, aux_p

        if seg.repeats > 1 and cfg.scan_layers:
            has_cache = seg_cache is not None

            def body(carry, xs):
                x, aux_c = carry
                if has_cache:
                    slot_params, slot_cache = xs
                else:
                    slot_params, slot_cache = xs, None
                x, ncs, aux_p = run_pattern(x, slot_params, slot_cache)
                ys = ncs if has_cache else 0
                return (x, jax.tree_util.tree_map(jnp.add, aux_c, aux_p)), ys

            if remat and cfg.remat_policy != "none":
                body = jax.checkpoint(body, policy=policy)
            xs = (seg_params["slots"], seg_cache["slots"]) if has_cache else seg_params["slots"]
            (x, aux_tot), ys = jax.lax.scan(body, (x, aux_tot), xs)
            if has_cache:
                new_cache.append({"slots": ys})
        else:
            x, ncs, aux_p = run_pattern(
                x, seg_params["slots"], seg_cache["slots"] if seg_cache is not None else None
            )
            aux_tot = jax.tree_util.tree_map(jnp.add, aux_tot, aux_p)
            if cache is not None:
                new_cache.append({"slots": ncs})
    return x, new_cache, aux_tot


# ---------------------------------------------------------------------------
# Entry points: embed -> segments -> head
# ---------------------------------------------------------------------------


def embed_inputs(params: PyTree, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    """Assemble the input activation sequence from the batch dict."""
    parts = []
    if "patch_embeddings" in batch:  # VLM stub frontend
        parts.append(batch["patch_embeddings"].astype(_dtype_of(cfg)))
    if "frame_embeddings" in batch:  # audio stub frontend
        parts.append(batch["frame_embeddings"].astype(_dtype_of(cfg)))
    if "tokens" in batch:
        table = params["embed"]
        if cfg.num_codebooks > 1:
            toks = batch["tokens"]  # [B, S, K]
            embs = [embed_tokens(table[k], toks[..., k]) for k in range(cfg.num_codebooks)]
            parts.append(sum(embs))
        else:
            parts.append(embed_tokens(table, batch["tokens"]))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    return x


def readout(params: PyTree, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.num_codebooks > 1:
        head = params["head"]  # [K, d, V]
        return jnp.einsum("bsd,kdv->bskv", x, head)
    if cfg.tie_embeddings:
        return logits_from_embedding(params["embed"], x, cfg.logit_softcap)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def forward(
    params: PyTree,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    cache: Optional[PyTree] = None,
    positions: Optional[jnp.ndarray] = None,
    remat: bool = False,
    apply_mode: Optional[str] = None,
    last_only: bool = False,
    capacity_per_row: bool = False,
):
    x = embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    x = hint(x, ("batch", "seq", "embed_act"))
    x, new_cache, aux = run_segments(
        params, x, cfg, positions, cache=cache, remat=remat,
        apply_mode=apply_mode, capacity_per_row=capacity_per_row,
    )
    if last_only:  # serving prefill: only the last position feeds sampling
        x = x[:, -1:, :]
    logits = readout(params, x, cfg)
    return logits, new_cache, aux


def loss_fn(
    params: PyTree,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    remat: bool = True,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, _, aux = forward(params, batch, cfg, remat=remat)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.num_codebooks > 1:
        # labels [B,S,K]; logits [B,S,K,V]
        ce, _ = cross_entropy_loss(logits, labels, mask=None)
    else:
        if "patch_embeddings" in batch:
            # VLM: loss only on the text tail
            n_text = labels.shape[1]
            logits = logits[:, -n_text:]
        ce, _ = cross_entropy_loss(logits, labels, mask=mask)
    loss = ce
    metrics = {"ce_loss": ce}
    if cfg.is_moe:
        m = cfg.moe
        loss = loss + m.aux_loss_coef * aux["load_balance_loss"]
        if m.router_z_loss_coef:
            loss = loss + m.router_z_loss_coef * aux["router_z_loss"]
        metrics["load_balance_loss"] = aux["load_balance_loss"]
    metrics["loss"] = loss
    return loss, metrics
