from .fault_tolerance import (
    FailureInjector,
    StragglerDetector,
    TrainSupervisor,
)

__all__ = ["FailureInjector", "StragglerDetector", "TrainSupervisor"]
