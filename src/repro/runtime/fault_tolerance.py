"""Fault tolerance: crash-resume supervision, straggler detection, injection.

At 1000+ nodes, failures are routine: the design here is checkpoint/restart
with an in-process supervisor (per-host) plus the job scheduler's re-exec on
hard faults.  Pieces:

* ``TrainSupervisor`` — wraps the step loop; on a step exception it restores
  the latest valid checkpoint and replays the data stream (the pipeline is
  index-deterministic so replay is exact), with bounded retry budget.
* ``StragglerDetector`` — EWMA step-time tracker; steps slower than
  ``threshold``x the EWMA are flagged (on real deployments the flag feeds
  the controller, which can cordon the slow host or trigger re-sharding —
  here we log and count).
* ``FailureInjector`` — deterministic fault injection for tests: raises at
  chosen steps to exercise the restore path.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

log = logging.getLogger("repro.runtime")


class FailureInjector:
    def __init__(self, fail_at_steps=(), exc=RuntimeError):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 5
    ewma: float = 0.0
    count: int = 0
    flagged: int = 0

    def record(self, step_time: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.count <= self.warmup:
            self.ewma = step_time if self.ewma == 0 else (
                (1 - self.alpha) * self.ewma + self.alpha * step_time
            )
            return False
        slow = step_time > self.threshold * self.ewma
        if slow:
            self.flagged += 1
            log.warning("straggler step: %.3fs vs ewma %.3fs", step_time, self.ewma)
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        return slow


class TrainSupervisor:
    """Crash-resume wrapper around a step function.

    ``state`` is any pytree-ish object; ``save_fn(step, state)`` and
    ``restore_fn() -> (step, state)`` plug into the Checkpointer;
    ``step_fn(step, state) -> state`` runs one training step (data access is
    by step index — deterministic replay).
    """

    def __init__(
        self,
        step_fn: Callable[[int, Any], Any],
        save_fn: Callable[[int, Any], None],
        restore_fn: Callable[[], Tuple[int, Any]],
        checkpoint_every: int = 100,
        max_restarts: int = 3,
        straggler: Optional[StragglerDetector] = None,
    ):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.straggler = straggler or StragglerDetector()
        self.restarts = 0
        self.history: list = []

    def run(self, state: Any, start_step: int, num_steps: int) -> Tuple[Any, int]:
        step = start_step
        end = start_step + num_steps
        while step < end:
            try:
                t0 = time.monotonic()
                state = self.step_fn(step, state)
                self.straggler.record(time.monotonic() - t0)
                step += 1
                if step % self.checkpoint_every == 0:
                    self.save_fn(step, state)
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — any step fault -> restore
                self.restarts += 1
                self.history.append((step, repr(e)))
                log.error("step %d failed (%s); restart %d/%d", step, e,
                          self.restarts, self.max_restarts)
                if self.restarts > self.max_restarts:
                    raise
                step, state = self.restore_fn()
        return state, step
