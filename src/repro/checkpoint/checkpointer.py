"""Sharding-aware, crash-safe checkpointing with async commit.

Layout (one directory per step):

    <dir>/step_000420.tmp/          written first
        shard_00000.npz             flat leaf arrays (this host's slice)
        manifest.json               treedef paths, shapes, dtypes, step
    <dir>/step_000420/              atomic rename on completion

Guarantees used by the fault-tolerance layer:
  * a checkpoint is visible iff its manifest landed via atomic rename —
    a crash mid-write leaves only a ``.tmp`` dir, which restore ignores;
  * ``save_async`` runs in a background thread (compute/IO overlap) and
    ``wait()`` joins before the next save (single writer);
  * restore validates shapes against the target tree and can RESHARD: a
    checkpoint written on one mesh loads onto any other mesh because leaves
    are stored unsharded per host and re-placed with the target shardings
    (elastic scaling path).

On a multi-host deployment each host writes ``shard_<proc>.npz`` with its
addressable slice; this container is single-host so shard 0 holds all data.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_SEP = "/"

# npz cannot represent ml_dtypes (bfloat16 etc.) natively: store such leaves
# as raw uint16/uint8 views and record the true dtype in the manifest.
_VIEW_ENCODE = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _VIEW_ENCODE:
        return arr.view(_VIEW_ENCODE[name]), name
    return arr, name


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_ENCODE:
        import ml_dtypes

        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            manifest = os.path.join(directory, name, "manifest.json")
            if os.path.exists(manifest):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, process_index: int = 0):
        self.directory = directory
        self.keep = keep
        self.process_index = process_index
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ------------------------------------------------------------------

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None) -> str:
        self.wait()  # serialize with any in-flight async writer
        flat, _ = _flatten_with_paths(tree)
        host_arrays = {}
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for key, leaf in flat:
            arr = np.asarray(jax.device_get(leaf))
            enc, dtype_name = _encode(arr)
            host_arrays[key] = enc
            manifest["leaves"][key] = {"shape": list(arr.shape), "dtype": dtype_name}
        tmp = os.path.join(self.directory, f"step_{step:06d}.tmp")
        final = os.path.join(self.directory, f"step_{step:06d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, f"shard_{self.process_index:05d}.npz"), **host_arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic visibility
        self._gc()
        return final

    def save_async(self, step: int, tree: PyTree, extra: Optional[Dict] = None):
        """Snapshot to host memory synchronously, write to disk in background."""
        self.wait()
        flat, _ = _flatten_with_paths(tree)
        snap = [(k, np.asarray(jax.device_get(v))) for k, v in flat]

        def write():
            try:
                tmp = os.path.join(self.directory, f"step_{step:06d}.tmp")
                final = os.path.join(self.directory, f"step_{step:06d}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                manifest = {"step": step, "extra": extra or {}, "leaves": {}}
                arrays = {}
                for k, arr in snap:
                    enc, dtype_name = _encode(arr)
                    arrays[k] = enc
                    manifest["leaves"][k] = {
                        "shape": list(arr.shape),
                        "dtype": dtype_name,
                    }
                np.savez(os.path.join(tmp, f"shard_{self.process_index:05d}.npz"),
                         **arrays)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:06d}"), ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def restore(
        self,
        step: int,
        target: PyTree,
        shardings: Optional[PyTree] = None,
    ) -> Tuple[PyTree, Dict]:
        """Load ``step`` into the structure of ``target``.

        ``shardings``: optional tree of NamedSharding — leaves are placed
        with ``jax.device_put`` onto the (possibly different) target mesh,
        which is the elastic-rescale path.
        """
        final = os.path.join(self.directory, f"step_{step:06d}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(final, f"shard_{self.process_index:05d}.npz"))
        flat, treedef = _flatten_with_paths(target)
        shard_flat = None
        if shardings is not None:
            shard_list, _ = _flatten_with_paths(shardings)
            shard_flat = dict(shard_list)
        leaves = []
        for key, leaf in flat:
            if key not in manifest["leaves"]:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = _decode(data[key], manifest["leaves"][key]["dtype"])
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {want}")
            if shard_flat is not None and key in shard_flat:
                leaves.append(jax.device_put(arr, shard_flat[key]))
            else:
                leaves.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target), leaves
        ), manifest["extra"]


def reshard(tree: PyTree, shardings: PyTree) -> PyTree:
    """Re-place a live pytree onto new shardings (elastic mesh change)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s), tree, shardings
    )


# ---------------------------------------------------------------------------
# Compressed-store persistence (compress-once / serve-many)
# ---------------------------------------------------------------------------
#
# The ResMoE pipeline's offline artifact: the FULL serving params tree
# after compress_model_params (and optionally quantize_compressed_params) —
# every LayerCompression's factored form (FusedLayerParams: center/u/v,
# plus the int8 scales) alongside the untouched dense weights. serve.py
# boots from this directory (--store-dir) instead of re-running the
# barycenter + SVD at every server start.
#
# Layout (same atomic-rename visibility contract as step checkpoints):
#
#     <dir>.tmp/store.npz + store_manifest.json   written first
#     <dir>/                                      atomic rename on completion

_STORE_MANIFEST = "store_manifest.json"
# v1: flat homogeneous store. v2 adds plan-aware meta: the serialized
# per-layer CompressionPlan (meta["plan"], core/plan.py JSON schema) plus
# num_experts / d_model for boot-time config validation. The loader
# accepts both; the writer emits v2 (docs/STORES.md).
_STORE_FORMAT_V1 = "resmoe-store-v1"
_STORE_FORMAT = "resmoe-store-v2"
_STORE_FORMATS = (_STORE_FORMAT_V1, _STORE_FORMAT)


def has_compressed_store(directory: str) -> bool:
    return os.path.exists(os.path.join(directory, _STORE_MANIFEST))


def save_compressed_store(directory: str, params: PyTree,
                          meta: Optional[Dict] = None) -> str:
    """Persist a (compressed, optionally int8) serving params tree.

    ``meta`` records boot-relevant facts (arch name, store_dtype, method,
    keep_ratio) so a loader can validate before serving. Overwrites an
    existing STORE atomically; a pre-existing directory that is not a
    store is refused (a mistyped path must never wipe unrelated data).
    """
    directory = directory.rstrip("/")
    if (os.path.isdir(directory) and os.listdir(directory)
            and not has_compressed_store(directory)):
        raise ValueError(
            f"refusing to overwrite {directory!r}: it is a non-empty "
            f"directory without a {_STORE_MANIFEST} — not a compressed "
            "store. Pick an empty or fresh path.")
    flat, _ = _flatten_with_paths(params)
    arrays = {}
    manifest = {"format": _STORE_FORMAT, "meta": meta or {}, "leaves": {}}
    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        enc, dtype_name = _encode(arr)
        arrays[key] = enc
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": dtype_name}
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "store.npz"), **arrays)
    with open(os.path.join(tmp, _STORE_MANIFEST), "w") as f:
        json.dump(manifest, f)
    # overwrite via rename-aside so a crash between steps never leaves a
    # window with NO store (rmtree-before-rename would): the old store
    # stays visible until the new one is renamed in.
    old = directory + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(directory):
        os.rename(directory, old)
    os.rename(tmp, directory)  # atomic visibility
    shutil.rmtree(old, ignore_errors=True)
    return directory


def _unflatten_keys(items: Dict[str, np.ndarray]) -> PyTree:
    """Rebuild the nested params tree from '/'-joined leaf paths.

    Dict nodes whose keys are a dense 0..n-1 integer range become lists
    (the treedef convention of _flatten_with_paths for list nodes —
    ``segments`` / ``slots`` in the params tree).
    """
    root: Dict = {}
    for key, arr in items.items():
        node = root
        parts = key.split(_SEP)
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr

    def fix(node):
        if not isinstance(node, dict):
            return node
        out = {k: fix(v) for k, v in node.items()}
        if out and all(k.isdigit() for k in out):
            idx = sorted(int(k) for k in out)
            if idx == list(range(len(idx))):
                return [out[str(i)] for i in idx]
        return out

    return fix(root)


def load_compressed_store(directory: str) -> Tuple[PyTree, Dict]:
    """Load a persisted store: (host-numpy params tree, meta dict).

    Leaves stay numpy — the caller device_puts them (Server does this via
    its rules/param_axes path, or jax promotes them lazily on first use).
    """
    manifest_path = os.path.join(directory, _STORE_MANIFEST)
    if not os.path.exists(manifest_path):
        raise FileNotFoundError(
            f"no compressed store at {directory!r} (missing "
            f"{_STORE_MANIFEST}; was the save interrupted? a crash "
            "mid-write leaves only a .tmp dir)")
    with open(manifest_path) as f:
        manifest = json.load(f)
    if manifest.get("format") not in _STORE_FORMATS:
        raise ValueError(f"unknown store format {manifest.get('format')!r} "
                         f"at {directory!r} (expected one of "
                         f"{_STORE_FORMATS})")
    data = np.load(os.path.join(directory, "store.npz"))
    leaves = {}
    for key, spec in manifest["leaves"].items():
        if key not in data.files:
            raise ValueError(
                f"store leaf {key!r} is named in {_STORE_MANIFEST} but "
                f"missing from store.npz at {directory!r} — corrupted "
                "store (truncated write? mixed files from two saves?)")
        arr = _decode(data[key], spec["dtype"])
        if list(arr.shape) != spec["shape"]:
            raise ValueError(
                f"store leaf {key}: shape {arr.shape} does not match "
                f"manifest {spec['shape']} — corrupted store")
        leaves[key] = arr
    return _unflatten_keys(leaves), manifest["meta"]


def validate_store_meta(meta: Dict, cfg) -> None:
    """Refuse a store whose recorded model shape disagrees with ``cfg``.

    Checks the v2 meta fields (num_experts, d_model) when present — a v1
    store without them passes (nothing to disagree with). Raises
    ValueError with both sides named; serve.py turns this into a clean
    boot failure instead of a shape error deep inside the forward pass.
    """
    checks = []
    if cfg.moe is not None:
        checks.append(("num_experts", cfg.moe.num_experts))
    checks.append(("d_model", cfg.d_model))
    for key, want in checks:
        got = meta.get(key)
        if got is not None and int(got) != int(want):
            raise ValueError(
                f"compressed store was built for {key}={got} but the "
                f"booting model config {cfg.name!r} has {key}={want} — "
                "wrong --store-dir for this --arch?")
