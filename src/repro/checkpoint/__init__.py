from .checkpointer import Checkpointer, latest_step, reshard

__all__ = ["Checkpointer", "latest_step", "reshard"]
