from .checkpointer import (
    Checkpointer,
    has_compressed_store,
    latest_step,
    load_compressed_store,
    reshard,
    save_compressed_store,
    validate_store_meta,
)

__all__ = [
    "Checkpointer",
    "has_compressed_store",
    "latest_step",
    "load_compressed_store",
    "reshard",
    "save_compressed_store",
    "validate_store_meta",
]
