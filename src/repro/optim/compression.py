"""Int8 error-feedback gradient compression for the DP all-reduce.

Distributed-optimization trick (DESIGN.md §5): before the data-parallel
all-reduce, each shard quantizes its local gradient to int8 with a per-tensor
scale; the all-reduce then moves 1/4 of the bf16 bytes (1/2 of fp16).  The
quantization error is carried in an *error-feedback* buffer and added back
into the next step's gradient, which restores convergence (Karimireddy et
al. 2019).

Usage is shard_map-scoped: ``compress_decompress_allreduce`` must run inside
a shard_map over the DP axis, where ``jax.lax.psum`` is the explicit
collective being shrunk.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class GradCompressionState(NamedTuple):
    error: PyTree  # per-leaf f32 error-feedback buffers


def init_grad_compression(params: PyTree) -> GradCompressionState:
    return GradCompressionState(
        error=jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def _quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress_allreduce(
    grads: PyTree,
    state: GradCompressionState,
    axis_name,
) -> Tuple[PyTree, GradCompressionState]:
    """psum int8-quantized grads with error feedback. Call inside shard_map."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = _quantize(g)
        deq = q.astype(jnp.float32) * scale
        new_e = g - deq
        # all-reduce the int8 payload (as int32 accumulate to avoid overflow)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        # scales are tiny; reduce them in f32 (max keeps dequant conservative)
        scale_sum = jax.lax.pmax(scale, axis_name)
        n = jax.lax.psum(1, axis_name)
        return (summed.astype(jnp.float32) * scale_sum / n), new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    return new_g, GradCompressionState(error=new_e)
