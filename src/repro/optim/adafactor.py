"""Adafactor (Shazeer & Stern 2018): factored second moment, O(m+n) state.

The default optimizer for the >=100B-parameter configs — second-moment
memory drops from O(mn) to O(m+n) per matrix, which is what lets the
llama3-405b / deepseek-v3 train cells fit v5e HBM (DESIGN.md §5).
Momentum is omitted (beta1=0), matching common large-scale practice.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128


def adafactor_init(params: PyTree) -> PyTree:
    def init(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "stats": jax.tree_util.tree_map(init, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adafactor_update(
    grads: PyTree,
    state: PyTree,
    params: PyTree,
    lr,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Tuple[PyTree, PyTree]:
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    beta2 = 1.0 - c ** (-decay)

    def upd(g, s, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if "vr" in s:
            vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(-1)
            vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(-2)
            # normalizer: sqrt((vr_i / mean_i vr) * vc_j)
            r = vr / jnp.clip(vr.mean(-1, keepdims=True), 1e-30)
            denom = r[..., :, None] * jnp.expand_dims(vc, -2)
            u = g / jnp.sqrt(jnp.maximum(denom, 1e-30))
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta2 * s["v"] + (1 - beta2) * g2
            u = g / jnp.sqrt(jnp.maximum(v, 1e-30))
            new_s = {"v": v}
        # update clipping (RMS <= clip_threshold)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        step = lr * u + lr * weight_decay * p.astype(jnp.float32)
        return new_s, (p.astype(jnp.float32) - step).astype(p.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_s = treedef.flatten_up_to(state["stats"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_stats = treedef.unflatten([o[0] for o in out])
    new_p = treedef.unflatten([o[1] for o in out])
    return new_p, {"stats": new_stats, "count": count}
