"""Optimizers + schedules (no external deps): AdamW, Adafactor, grad utils."""
from .adamw import adamw_init, adamw_update
from .adafactor import adafactor_init, adafactor_update
from .api import Optimizer, make_optimizer
from .compression import (
    GradCompressionState,
    compress_decompress_allreduce,
    init_grad_compression,
)
from .schedule import cosine_warmup_schedule

__all__ = [
    "adamw_init",
    "adamw_update",
    "adafactor_init",
    "adafactor_update",
    "Optimizer",
    "make_optimizer",
    "GradCompressionState",
    "compress_decompress_allreduce",
    "init_grad_compression",
    "cosine_warmup_schedule",
]
