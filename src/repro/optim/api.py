"""Optimizer facade: name -> (init, update) with global-norm clipping."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .adafactor import adafactor_init, adafactor_update
from .adamw import adamw_init, adamw_update

PyTree = Any


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


@dataclasses.dataclass
class Optimizer:
    name: str
    lr_fn: Callable
    weight_decay: float = 0.01
    clip_norm: float = 1.0

    def init(self, params: PyTree) -> PyTree:
        if self.name == "adamw":
            return adamw_init(params)
        if self.name == "adafactor":
            return adafactor_init(params)
        raise ValueError(self.name)

    def update(self, grads: PyTree, state: PyTree, params: PyTree):
        step = state["count"]
        lr = self.lr_fn(step)
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        if self.name == "adamw":
            new_p, new_s = adamw_update(grads, state, params, lr,
                                        weight_decay=self.weight_decay)
        elif self.name == "adafactor":
            new_p, new_s = adafactor_update(grads, state, params, lr,
                                            weight_decay=self.weight_decay)
        else:
            raise ValueError(self.name)
        return new_p, new_s, {"grad_norm": gnorm, "lr": lr}


def make_optimizer(name: str, lr_fn, weight_decay: float = 0.01,
                   clip_norm: float = 1.0) -> Optimizer:
    return Optimizer(name=name, lr_fn=lr_fn, weight_decay=weight_decay,
                     clip_norm=clip_norm)
