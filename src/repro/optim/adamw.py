"""AdamW (decoupled weight decay), f32 moments, bf16-safe."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def adamw_init(params: PyTree) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads: PyTree,
    state: PyTree,
    params: PyTree,
    lr,
    b1: float = 0.9,
    b2: float = 0.98,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Tuple[PyTree, PyTree]:
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_p = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
