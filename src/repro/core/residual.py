"""Residual compressors: unstructured prune, TPU block prune (BCSR), SVD.

All compressors consume a residual matrix ``delta = T_k W_k - W_omega`` of
shape [p_I, d_design] and emit a ``CompressedResidual`` that knows how to
(1) reconstruct a dense approximation, (2) report its true storage cost in
bytes, and (3) expose raw factors for the fused kernels.

Parameter accounting matches Appendix A.3/A.4 of the paper: a keep_ratio of
0.25 means the stored representation holds ~25% of the residual's entries
(UP/block: nonzeros; SVD: rank chosen so k*(m+n) = 0.25*m*n).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class CompressedResidual:
    method: str  # "up" | "block" | "svd" | "none"
    shape: Tuple[int, int]
    # up: dense masked matrix (and the mask); storage accounted as CSR-int32.
    dense: Optional[np.ndarray] = None
    nnz: int = 0
    # block (BCSR): values [nblocks, bm, bn] + block col idx + row ptr.
    block_values: Optional[np.ndarray] = None
    block_col_idx: Optional[np.ndarray] = None
    block_row_ptr: Optional[np.ndarray] = None
    block_shape: Tuple[int, int] = (8, 128)
    # svd: delta ~= u @ v, u: [m, r], v: [r, n]
    u: Optional[np.ndarray] = None
    v: Optional[np.ndarray] = None

    # -- reconstruction ------------------------------------------------------

    def to_dense(self) -> np.ndarray:
        m, n = self.shape
        if self.method == "none":
            return np.zeros(self.shape, dtype=np.float32)
        if self.method == "up":
            return np.asarray(self.dense)
        if self.method == "svd":
            return np.asarray(self.u) @ np.asarray(self.v)
        if self.method == "block":
            bm, bn = self.block_shape
            out = np.zeros((m, n), dtype=np.float32)
            nb_rows = m // bm
            for br in range(nb_rows):
                s, e = int(self.block_row_ptr[br]), int(self.block_row_ptr[br + 1])
                for p in range(s, e):
                    bc = int(self.block_col_idx[p])
                    out[br * bm : (br + 1) * bm, bc * bn : (bc + 1) * bn] = self.block_values[p]
            return out
        raise ValueError(self.method)

    # -- storage accounting (bytes) ------------------------------------------

    def storage_bytes(self, dtype_bytes: int = 2) -> int:
        m, n = self.shape
        if self.method == "none":
            return 0
        if self.method == "up":
            # CSR: values + int32 col idx per nnz + int32 row ptr.
            return self.nnz * (dtype_bytes + 4) + (m + 1) * 4
        if self.method == "svd":
            r = self.u.shape[1]
            return r * (m + n) * dtype_bytes
        if self.method == "block":
            bm, bn = self.block_shape
            nb = self.block_values.shape[0]
            return nb * bm * bn * dtype_bytes + nb * 4 + (m // bm + 1) * 4
        raise ValueError(self.method)

    def num_params(self) -> int:
        if self.method == "none":
            return 0
        if self.method == "up":
            return int(self.nnz)
        if self.method == "svd":
            return int(self.u.size + self.v.size)
        if self.method == "block":
            return int(self.block_values.size)
        raise ValueError(self.method)


# ---------------------------------------------------------------------------
# Unstructured magnitude pruning (paper's UP; Han et al. 2015)
# ---------------------------------------------------------------------------


def prune_unstructured(delta: np.ndarray, keep_ratio: float) -> CompressedResidual:
    d = np.asarray(delta, dtype=np.float32)
    k = max(1, int(round(keep_ratio * d.size)))
    if k >= d.size:
        return CompressedResidual(method="up", shape=d.shape, dense=d.copy(), nnz=int(d.size))
    flat = np.abs(d).ravel()
    # threshold = k-th largest magnitude
    thresh = np.partition(flat, d.size - k)[d.size - k]
    mask = np.abs(d) >= thresh
    # resolve ties deterministically to exactly k entries
    extra = int(mask.sum()) - k
    if extra > 0:
        tie_idx = np.flatnonzero((np.abs(d) == thresh).ravel())[:extra]
        mask.ravel()[tie_idx] = False
    out = np.where(mask, d, 0.0).astype(np.float32)
    return CompressedResidual(method="up", shape=d.shape, dense=out, nnz=int(mask.sum()))


# ---------------------------------------------------------------------------
# Block-structured pruning (TPU adaptation — see DESIGN.md §4.1)
# ---------------------------------------------------------------------------


def prune_block(
    delta: np.ndarray, keep_ratio: float, block_shape: Tuple[int, int] = (8, 128)
) -> CompressedResidual:
    """Keep the top blocks by Frobenius norm so that kept params ~= ratio.

    The matrix is zero-padded to a block multiple for scoring; emitted BCSR
    blocks are tile-aligned for the Pallas kernel.
    """
    d = np.asarray(delta, dtype=np.float32)
    m, n = d.shape
    bm, bn = block_shape
    pm, pn = (-m) % bm, (-n) % bn
    dp = np.pad(d, ((0, pm), (0, pn)))
    mb, nb = dp.shape[0] // bm, dp.shape[1] // bn
    blocks = dp.reshape(mb, bm, nb, bn).transpose(0, 2, 1, 3)  # [mb, nb, bm, bn]
    scores = (blocks.astype(np.float64) ** 2).sum(axis=(2, 3))
    total_blocks = mb * nb
    k = max(1, int(round(keep_ratio * total_blocks)))
    flat = scores.ravel()
    keep_idx = np.argsort(-flat, kind="stable")[:k]
    keep_mask = np.zeros(total_blocks, dtype=bool)
    keep_mask[keep_idx] = True
    keep_mask = keep_mask.reshape(mb, nb)

    values, col_idx, row_ptr = [], [], [0]
    for br in range(mb):
        for bc in range(nb):
            if keep_mask[br, bc]:
                values.append(blocks[br, bc])
                col_idx.append(bc)
        row_ptr.append(len(col_idx))
    return CompressedResidual(
        method="block",
        shape=(dp.shape[0], dp.shape[1]),
        block_values=np.stack(values).astype(np.float32),
        block_col_idx=np.asarray(col_idx, dtype=np.int32),
        block_row_ptr=np.asarray(row_ptr, dtype=np.int32),
        block_shape=block_shape,
    )


# ---------------------------------------------------------------------------
# Truncated SVD (paper's SVD variant; Denton et al. 2014)
# ---------------------------------------------------------------------------


def svd_rank_for_ratio(m: int, n: int, keep_ratio: float) -> int:
    """Rank r such that r*(m+n) ~= keep_ratio*m*n (Appendix A.4)."""
    return max(1, int(round(keep_ratio * m * n / (m + n))))


def compress_svd(
    delta: np.ndarray, keep_ratio: float, rank: Optional[int] = None
) -> CompressedResidual:
    d = np.asarray(delta, dtype=np.float32)
    m, n = d.shape
    r = rank if rank is not None else svd_rank_for_ratio(m, n, keep_ratio)
    r = min(r, min(m, n))
    u, s, vt = np.linalg.svd(d.astype(np.float64), full_matrices=False)
    sq = np.sqrt(s[:r])
    uu = (u[:, :r] * sq[None, :]).astype(np.float32)
    vv = (sq[:, None] * vt[:r]).astype(np.float32)
    return CompressedResidual(method="svd", shape=(m, n), u=uu, v=vv)


def compress_residual(
    delta: np.ndarray,
    method: str,
    keep_ratio: float,
    block_shape: Tuple[int, int] = (8, 128),
    rank: Optional[int] = None,
) -> CompressedResidual:
    if method == "up":
        return prune_unstructured(delta, keep_ratio)
    if method == "block":
        return prune_block(delta, keep_ratio, block_shape)
    if method == "svd":
        return compress_svd(delta, keep_ratio, rank)
    if method == "none":
        return CompressedResidual(method="none", shape=tuple(delta.shape))
    raise ValueError(f"unknown residual method: {method}")
