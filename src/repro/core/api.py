"""Public API: compress a built model's parameter pytree with ResMoE.

The compressor walks a model param tree (as produced by
``repro.models.model.build_model(cfg).init``), finds MoE expert banks (and,
for the beyond-paper ``cross_layer`` scope, stacked dense FFNs), and replaces
them with a compressed store understood by the MoE forward paths.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from ..configs.base import ModelConfig, ResMoEConfig
from .compress import LayerCompression, compress_bank

PyTree = Any


@dataclasses.dataclass
class CompressionReport:
    layers: List[Dict[str, float]]
    original_bytes: int
    compressed_bytes: int
    mean_approx_error: float

    @property
    def ratio(self) -> float:
        return self.compressed_bytes / max(1, self.original_bytes)

    def summary(self) -> str:
        return (
            f"ResMoE: {self.original_bytes/2**20:.1f} MiB -> "
            f"{self.compressed_bytes/2**20:.1f} MiB "
            f"({self.ratio:.3f}x), approx_err={self.mean_approx_error:.4g}"
        )


class ResMoECompressor:
    """One-shot, data-agnostic compression of MoE expert banks."""

    def __init__(self, cfg: ResMoEConfig, center: str = "wb"):
        self.cfg = cfg
        self.center = center

    # -- single bank ---------------------------------------------------------

    def compress_bank(self, bank: Dict[str, np.ndarray], seed: int = 0) -> LayerCompression:
        return compress_bank(
            bank,
            method=self.cfg.method,
            keep_ratio=self.cfg.keep_ratio,
            center=self.center,
            barycenter_iters=self.cfg.barycenter_iters,
            ot_solver=self.cfg.ot_solver,
            block_shape=self.cfg.block_shape,
            seed=seed,
        )

    # -- whole model ---------------------------------------------------------

    def compress_params(
        self, params: PyTree, model_cfg: ModelConfig
    ) -> tuple[PyTree, CompressionReport]:
        """Replace every MoE expert bank in a repro.models param tree with
        its ResMoE compressed store (delegates to the model-layout adapter)."""
        import dataclasses as _dc

        from ..models.model import compress_model_params

        cfg = _dc.replace(model_cfg, resmoe=self.cfg)
        return compress_model_params(params, cfg, center=self.center)


def compress_model(params: PyTree, model_cfg: ModelConfig):
    """Convenience entry point: compress using ``model_cfg.resmoe``."""
    from ..models.model import compress_model_params

    return compress_model_params(params, model_cfg)
