"""Trimming heuristics: what to DROP before slimming what remains.

Unified-MoE-Compression's ablation (PAPERS.md) shows "Expert Trimming" —
removing whole experts, layers, or blocks — composes with "Expert
Slimming" like ResMoE's low-rank residuals; SEER-MoE reaches the same
conclusion from the regularization side. This module holds the pure
scoring/selection logic; the model-running capture lives in
models/model.py (``block_hidden_similarities``) so core never imports
models.

Two tiers:

* **block drop** — rank transformer blocks by mean token cosine between
  block input and block output hidden states (a block that barely rotates
  the residual stream is nearly the identity and can be removed — the
  block-drop recipe of Unified-MoE-Compression).
* **expert drop** — rank experts within a layer by residual energy
  ``||aligned_k - center||_F^2`` against the Wasserstein barycenter; the
  paper's §5.4 observation is that some experts are nearly the barycenter
  already, so serving them AS the center (via the store's expert_map
  remap) is almost free.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np


def hidden_state_similarity(h_in: np.ndarray, h_out: np.ndarray) -> float:
    """Mean token cosine similarity between a block's input and output.

    ``h_in``/``h_out`` are [..., tokens, d_model]; high similarity means
    the block barely changes the residual stream.
    """
    a = np.asarray(h_in, dtype=np.float64).reshape(-1, h_in.shape[-1])
    b = np.asarray(h_out, dtype=np.float64).reshape(-1, h_out.shape[-1])
    if a.shape != b.shape:
        raise ValueError(
            f"hidden-state shapes disagree: {h_in.shape} vs {h_out.shape}")
    num = (a * b).sum(axis=-1)
    den = np.linalg.norm(a, axis=-1) * np.linalg.norm(b, axis=-1)
    return float(np.mean(num / np.maximum(den, 1e-12)))


def select_dropped_blocks(
    similarities: Sequence[float],
    num_drop: int,
    protect: Sequence[int] = (),
) -> Tuple[int, ...]:
    """Pick the ``num_drop`` most-redundant blocks (highest similarity).

    ``protect`` shields layers from dropping regardless of score (e.g. the
    first/last block, or non-MoE layers the caller wants intact).
    """
    if num_drop < 0:
        raise ValueError(f"num_drop must be >= 0, got {num_drop}")
    protected = set(int(i) for i in protect)
    eligible = [i for i in range(len(similarities)) if i not in protected]
    if num_drop > len(eligible):
        raise ValueError(
            f"cannot drop {num_drop} of {len(eligible)} unprotected blocks")
    order = sorted(eligible, key=lambda i: -float(similarities[i]))
    return tuple(sorted(order[:num_drop]))


def expert_residual_energy(
    design: np.ndarray,
    center: np.ndarray,
    perms: np.ndarray,
) -> np.ndarray:
    """Per-expert ``||design[k][perms[k]] - center||_F^2`` ([num_experts]).

    ``design`` is the [N, f, d_design] design-matrix stack
    (core/compress.py::design_matrices), ``center``/``perms`` come from the
    barycenter result — the same alignment the store is built against.
    """
    n = design.shape[0]
    out = np.empty((n,), dtype=np.float64)
    for k in range(n):
        diff = np.asarray(design[k])[np.asarray(perms[k])] - center
        out[k] = float((diff * diff).sum())
    return out


def select_dropped_experts(
    energies: np.ndarray,
    num_drop: int,
) -> Tuple[int, ...]:
    """Pick the ``num_drop`` experts CLOSEST to the center (lowest energy)."""
    if num_drop < 0:
        raise ValueError(f"num_drop must be >= 0, got {num_drop}")
    n = len(energies)
    if num_drop >= n:
        raise ValueError(
            f"cannot drop {num_drop} of {n} experts — at least one must "
            "remain (use drop_block for a center-only layer)")
    order = np.argsort(np.asarray(energies, dtype=np.float64), kind="stable")
    return tuple(sorted(int(i) for i in order[:num_drop]))
