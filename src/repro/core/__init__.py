"""ResMoE core: Wasserstein-barycenter extraction + residual restoration."""
from .api import CompressionReport, ResMoECompressor, compress_model
from .barycenter import (
    BarycenterResult,
    average_center,
    reference_center,
    wasserstein_barycenter,
)
from .compress import (
    LayerCompression,
    compress_bank,
    design_matrices,
    fused_params,
    restored_bank,
    split_design,
)
from .ot import exact_assignment, ot_permutation, sinkhorn
from .plan import (
    TRIM_TIERS,
    CompressionPlan,
    LayerRecipe,
    PlanCandidate,
    layer_candidates,
    recipe_store_bytes,
    solve_plan,
)
from .quant import (
    STORE_DTYPES,
    dequantize_int8,
    dequantize_store,
    int8_error_bound,
    is_quantized_store,
    quantize_int8,
    quantize_store,
)
from .residual import (
    CompressedResidual,
    compress_residual,
    compress_svd,
    prune_block,
    prune_unstructured,
    svd_rank_for_ratio,
)
from .trim import (
    expert_residual_energy,
    hidden_state_similarity,
    select_dropped_blocks,
    select_dropped_experts,
)

__all__ = [
    "CompressionReport",
    "ResMoECompressor",
    "compress_model",
    "BarycenterResult",
    "average_center",
    "reference_center",
    "wasserstein_barycenter",
    "LayerCompression",
    "compress_bank",
    "design_matrices",
    "fused_params",
    "restored_bank",
    "split_design",
    "exact_assignment",
    "ot_permutation",
    "sinkhorn",
    "TRIM_TIERS",
    "CompressionPlan",
    "LayerRecipe",
    "PlanCandidate",
    "layer_candidates",
    "recipe_store_bytes",
    "solve_plan",
    "expert_residual_energy",
    "hidden_state_similarity",
    "select_dropped_blocks",
    "select_dropped_experts",
    "STORE_DTYPES",
    "dequantize_int8",
    "dequantize_store",
    "int8_error_bound",
    "is_quantized_store",
    "quantize_int8",
    "quantize_store",
    "CompressedResidual",
    "compress_residual",
    "compress_svd",
    "prune_block",
    "prune_unstructured",
    "svd_rank_for_ratio",
]
