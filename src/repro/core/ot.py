"""Optimal transport solvers used by the Wasserstein-barycenter extraction.

ResMoE only ever needs OT between two *uniform* discrete distributions with
*equal* support size ``p_I`` (the rows of two expert design matrices).  By
Peyre & Cuturi Prop. 2.1 the optimal plan is then ``1/p_I`` times a
permutation matrix, so the problem reduces to a linear assignment:

    pi = argmin_{pi in S_{p_I}} sum_i || X[pi(i)] - Y[i] ||^2

We provide:
  * ``exact_assignment``     — Jonker–Volgenant via scipy (host, float64).
  * ``auction_assignment``   — pure-numpy auction algorithm fallback/oracle.
  * ``sinkhorn``             — entropic OT in JAX (jittable, differentiable),
                               with ``round_to_permutation`` for large p_I.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:  # scipy is available in this environment; keep the fallback honest.
    from scipy.optimize import linear_sum_assignment as _lsa

    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _HAVE_SCIPY = False


# ---------------------------------------------------------------------------
# Cost matrices
# ---------------------------------------------------------------------------


def pairwise_sq_dists(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """||x_i - y_j||^2 cost matrix, numerically-stable expansion."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    x2 = (x * x).sum(-1)[:, None]
    y2 = (y * y).sum(-1)[None, :]
    c = x2 + y2 - 2.0 * (x @ y.T)
    return np.maximum(c, 0.0)


def pairwise_sq_dists_jax(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    x2 = (x * x).sum(-1)[:, None]
    y2 = (y * y).sum(-1)[None, :]
    return jnp.maximum(x2 + y2 - 2.0 * (x @ y.T), 0.0)


# ---------------------------------------------------------------------------
# Exact assignment
# ---------------------------------------------------------------------------


def exact_assignment(cost: np.ndarray) -> np.ndarray:
    """Return ``perm`` s.t. row ``perm[j]`` of the source matches target ``j``.

    Minimizes sum_j cost[perm[j], j].
    """
    if _HAVE_SCIPY:
        rows, cols = _lsa(np.asarray(cost, dtype=np.float64))
        perm = np.empty(cost.shape[0], dtype=np.int64)
        perm[cols] = rows
        return perm
    return auction_assignment(cost)


def auction_assignment(cost: np.ndarray, eps_start: float = 1.0) -> np.ndarray:
    """Bertsekas auction algorithm (minimization form) — numpy fallback.

    O(n^2) per round with eps-scaling; exact for integer-scaled costs, and
    within n*eps of optimal in general. Used as an independent oracle in
    tests and as a scipy-free fallback.
    """
    c = np.asarray(cost, dtype=np.float64)
    n = c.shape[0]
    benefit = -c  # auction maximizes
    scale = max(1.0, np.abs(benefit).max())
    eps = eps_start * scale
    prices = np.zeros(n)
    owner = np.full(n, -1, dtype=np.int64)  # owner[j] = row assigned to col j
    assigned_col = np.full(n, -1, dtype=np.int64)
    eps_min = scale / (2.0 * n * n) + 1e-12
    while True:
        owner[:] = -1
        assigned_col[:] = -1
        unassigned = list(range(n))
        while unassigned:
            i = unassigned.pop()
            values = benefit[i] - prices
            j = int(np.argmax(values))
            v1 = values[j]
            values[j] = -np.inf
            v2 = values.max() if n > 1 else v1
            prices[j] += (v1 - v2) + eps
            prev = owner[j]
            owner[j] = i
            assigned_col[i] = j
            if prev >= 0:
                assigned_col[prev] = -1
                unassigned.append(prev)
        if eps <= eps_min:
            break
        eps = max(eps / 4.0, eps_min)
    perm = np.empty(n, dtype=np.int64)
    for j in range(n):
        perm[j] = owner[j]
    return perm


def assignment_to_matrix(perm: np.ndarray) -> np.ndarray:
    """T[j, perm[j]] = 1 : row-permutation matrix s.t. (T @ X)[j] = X[perm[j]]."""
    n = perm.shape[0]
    t = np.zeros((n, n), dtype=np.float64)
    t[np.arange(n), perm] = 1.0
    return t


# ---------------------------------------------------------------------------
# Entropic OT (JAX)
# ---------------------------------------------------------------------------


@jax.jit
def sinkhorn(
    cost: jnp.ndarray,
    reg: float = 0.01,
    num_iters: int = 200,
) -> jnp.ndarray:
    """Log-domain Sinkhorn between two uniform distributions of equal size.

    Returns the (dense) transport plan, row/col sums = 1/n.
    """
    n, m = cost.shape
    log_a = jnp.full((n,), -jnp.log(n))
    log_b = jnp.full((m,), -jnp.log(m))
    # scale reg by median cost for shape-independent behaviour
    med = jnp.median(cost) + 1e-30
    eps = reg * med
    log_k = -cost / eps

    def body(_, fg):
        f, g = fg
        f = eps * (log_a - jax.scipy.special.logsumexp((log_k + g[None, :] / eps), axis=1))
        g = eps * (log_b - jax.scipy.special.logsumexp((log_k + f[:, None] / eps), axis=0))
        return f, g

    f0 = jnp.zeros((n,))
    g0 = jnp.zeros((m,))
    f, g = jax.lax.fori_loop(0, num_iters, body, (f0, g0))
    return jnp.exp(log_k + f[:, None] / eps + g[None, :] / eps)


def round_plan_to_permutation(plan: np.ndarray) -> np.ndarray:
    """Greedy rounding of a (near-)permutation plan to an exact permutation.

    Returns ``perm`` with target-j matched to source ``perm[j]`` (same
    convention as :func:`exact_assignment`, with plan[i, j] mass from source
    i to target j).
    """
    p = np.asarray(plan, dtype=np.float64).copy()
    n = p.shape[0]
    perm = np.full(n, -1, dtype=np.int64)
    # take matches in decreasing mass order
    order = np.argsort(-p, axis=None)
    used_i = np.zeros(n, dtype=bool)
    used_j = np.zeros(n, dtype=bool)
    count = 0
    for flat in order:
        i, j = divmod(int(flat), n)
        if not used_i[i] and not used_j[j]:
            perm[j] = i
            used_i[i] = True
            used_j[j] = True
            count += 1
            if count == n:
                break
    return perm


def ot_permutation(
    x: np.ndarray,
    y: np.ndarray,
    solver: str = "exact",
    reg: float = 0.01,
    iters: int = 200,
) -> np.ndarray:
    """Permutation aligning source rows ``x`` to target rows ``y``.

    Returns ``perm`` with ``x[perm]`` row-aligned to ``y`` — i.e. ``perm`` is
    T_k of the paper in index form (T_k @ X = X[perm]).
    """
    cost = pairwise_sq_dists(x, y)
    if solver == "exact":
        return exact_assignment(cost)
    if solver == "sinkhorn":
        plan = np.asarray(sinkhorn(jnp.asarray(cost, jnp.float32), reg, iters))
        return round_plan_to_permutation(plan)
    raise ValueError(f"unknown OT solver: {solver}")
