"""Per-layer compression plans: trimming tiers + rank/dtype under a budget.

The paper compresses every MoE layer with one global ``keep_ratio``, but its
own §5.4 observes that residual energy is far from uniform across layers and
experts — some experts are nearly the barycenter already. A
:class:`CompressionPlan` turns that observation into a deployable artifact:
one :class:`LayerRecipe` per transformer layer, each naming

  * ``rank``        — this layer's truncated-SVD residual rank (None =
                      derive from the global ``keep_ratio``);
  * ``store_dtype`` — this layer's serving-store dtype (fp32 / int8);
  * ``drop_experts``— experts whose residual factors are removed entirely.
                      Router logits are NOT retrained: the store carries an
                      ``expert_map`` remap so a dropped expert resolves to
                      the shared barycenter center, which is free — the
                      center is already resident for the spec-decode
                      drafter (models/moe.py, DESIGN.md §12);
  * ``drop_block``  — the whole transformer block is removed (selected by
                      hidden-state similarity, core/trim.py — the
                      Unified-MoE-Compression "Expert Trimming" recipe).

Plans ride on ``ResMoEConfig.plan``; models/transformer.py attaches each
recipe to its LayerSpec, which automatically splits scanned segments only
where recipes actually differ. checkpoint/checkpointer.py persists the plan
in the v2 store manifest so ``serve.py --store-dir`` boots any point on the
memory/quality frontier without recompression.

:func:`solve_plan` is the greedy byte-budget search over per-layer
candidate (rank, dtype) settings scored by the same approximation-error
metric as benchmarks/approx_error.py; benchmarks/frontier.py composes it
with the downstream-eval harness and asserts the searched plan
Pareto-dominates the best uniform setting at equal byte budget.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import ResMoEConfig

# The plan dimensions scripts/check_parity_matrix.py requires a
# `# PARITY: plan/<tier>` differential test for — adding a trimming tier
# here fails the docs CI tier until a parity test covers it.
TRIM_TIERS = ("rank", "dtype", "expert", "block")


# ---------------------------------------------------------------------------
# Recipes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerRecipe:
    """Compression settings for ONE transformer layer.

    Frozen and hashable so it can ride on models/transformer.py's
    LayerSpec: two layers stack into one scanned segment iff their whole
    specs — recipes included — compare equal.
    """

    rank: Optional[int] = None
    store_dtype: str = "fp32"
    drop_experts: Tuple[int, ...] = ()
    drop_block: bool = False

    def __post_init__(self):
        if self.rank is not None and self.rank < 1:
            raise ValueError(
                f"LayerRecipe.rank must be >= 1, got {self.rank!r} — rank 0 "
                "stores nothing; drop the experts or the block instead")
        if self.store_dtype not in ResMoEConfig.STORE_DTYPES:
            raise ValueError(
                f"LayerRecipe.store_dtype {self.store_dtype!r} not in "
                f"{ResMoEConfig.STORE_DTYPES}")
        drops = tuple(int(e) for e in self.drop_experts)
        if len(set(drops)) != len(drops) or any(e < 0 for e in drops):
            raise ValueError(
                f"LayerRecipe.drop_experts must be distinct non-negative "
                f"expert indices, got {self.drop_experts!r}")
        # canonical order: recipes that drop the same set compare equal
        object.__setattr__(self, "drop_experts", tuple(sorted(drops)))

    @property
    def is_default(self) -> bool:
        """True when this recipe changes nothing vs the global config."""
        return (self.rank is None and self.store_dtype == "fp32"
                and not self.drop_experts and not self.drop_block)

    def to_json(self) -> Dict:
        return {
            "rank": self.rank,
            "store_dtype": self.store_dtype,
            "drop_experts": list(self.drop_experts),
            "drop_block": bool(self.drop_block),
        }

    @classmethod
    def from_json(cls, obj: Dict) -> "LayerRecipe":
        return cls(
            rank=obj.get("rank"),
            store_dtype=obj.get("store_dtype", "fp32"),
            drop_experts=tuple(obj.get("drop_experts", ())),
            drop_block=bool(obj.get("drop_block", False)),
        )


@dataclasses.dataclass(frozen=True)
class CompressionPlan:
    """One LayerRecipe per ORIGINAL layer index (length = cfg.num_layers).

    Dropped blocks keep their slot in ``recipes`` — the plan is indexed by
    the dense model's layer order, and models/transformer.py omits dropped
    layers when it builds the serving layer list.
    """

    recipes: Tuple[LayerRecipe, ...]

    def __post_init__(self):
        recipes = tuple(self.recipes)
        if not recipes:
            raise ValueError("CompressionPlan needs at least one recipe")
        if not all(isinstance(r, LayerRecipe) for r in recipes):
            raise TypeError("CompressionPlan.recipes must be LayerRecipes")
        object.__setattr__(self, "recipes", recipes)

    @property
    def num_layers(self) -> int:
        return len(self.recipes)

    def validate(self, num_layers: int, num_experts: Optional[int] = None):
        """Structural checks against a model's shape (clear, early errors)."""
        if len(self.recipes) != num_layers:
            raise ValueError(
                f"plan has {len(self.recipes)} recipes but the model has "
                f"{num_layers} layers — one recipe per ORIGINAL layer, "
                "dropped blocks included")
        if all(r.drop_block for r in self.recipes):
            raise ValueError("plan drops every block — nothing left to serve")
        if num_experts is not None:
            for i, r in enumerate(self.recipes):
                if any(e >= num_experts for e in r.drop_experts):
                    raise ValueError(
                        f"plan layer {i} drops expert(s) "
                        f"{[e for e in r.drop_experts if e >= num_experts]} "
                        f"but the model has only {num_experts} experts")
                if len(r.drop_experts) >= num_experts:
                    raise ValueError(
                        f"plan layer {i} drops all {num_experts} experts — "
                        "use drop_block (or apply_mode='center_only') for a "
                        "center-only layer")

    def to_json(self) -> Dict:
        return {"layers": [r.to_json() for r in self.recipes]}

    @classmethod
    def from_json(cls, obj: Dict) -> "CompressionPlan":
        return cls(tuple(LayerRecipe.from_json(r) for r in obj["layers"]))

    @classmethod
    def uniform(cls, num_layers: int, rank: Optional[int] = None,
                store_dtype: str = "fp32") -> "CompressionPlan":
        return cls(tuple(LayerRecipe(rank=rank, store_dtype=store_dtype)
                         for _ in range(num_layers)))


# ---------------------------------------------------------------------------
# Byte accounting + per-layer candidate scoring
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"fp32": 4, "int8": 1}
_SCALE_BYTES = 4  # fp32 per-channel scales of the int8 store


def recipe_store_bytes(segs: Sequence[Tuple[str, int]], f: int, e_kept: int,
                       rank: int, store_dtype: str,
                       num_experts: Optional[int] = None) -> int:
    """Serving-store *factor* bytes of one layer under a candidate setting.

    Counts center + u + v (+ int8 scales + the trim remap) — the bytes a
    plan actually moves. Fixed per-layer costs (router, norms, biases) are
    identical across candidates and budget-neutral, so they are excluded;
    benchmarks/frontier.py reports the measured on-disk store size
    alongside this analytic accounting.

    ``segs`` is the design-matrix segment list (core/compress.py::
    bank_design_dims) — (name, width) pairs whose widths sum to d_design.
    """
    ib = _DTYPE_BYTES[store_dtype]
    dd = sum(w for _, w in segs)
    n = f * dd * ib                      # center, all segments
    n += e_kept * f * rank * ib          # u
    n += e_kept * rank * dd * ib         # v, all segments
    if store_dtype == "int8":
        # center scales: one per output channel per segment (w1/w3 -> f,
        # w2 -> d); u/v scales: [E, r] per factor (core/quant.py)
        for name, width in segs:
            n += (width if name == "w2" else f) * _SCALE_BYTES
        n += e_kept * rank * _SCALE_BYTES            # u_scale
        n += len(segs) * e_kept * rank * _SCALE_BYTES  # v_scale per segment
    if num_experts is not None and e_kept < num_experts:
        n += num_experts * 4  # int32 expert_map remap
    return int(n)


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One (recipe, cost, score) point on a layer's frontier."""

    recipe: LayerRecipe
    bytes: int
    error: float


def _fake_quant(x: np.ndarray, reduce_axis: int) -> np.ndarray:
    """dequant(quant(x)) in numpy — the int8 scoring surrogate."""
    from .quant import quantize_int8

    q, s = quantize_int8(x, reduce_axis)
    return q.astype(np.float32) * np.expand_dims(s, reduce_axis)


def _fake_quant_center(center: np.ndarray,
                       segs: Sequence[Tuple[str, int]]) -> np.ndarray:
    """Per-segment int8 round-trip of a design-layout center [f, dd].

    Mirrors core/quant.py's model-layout channel choice: w1/w3 segments
    quantize per row (the store's [d, f] output channel = the design's f
    row), w2 per column. Width-1 bias segments stay fp32 (the store never
    quantizes them).
    """
    parts = []
    col = 0
    for name, width in segs:
        chunk = center[:, col:col + width]
        col += width
        if name in ("b1", "b3"):
            parts.append(chunk)
        elif name == "w2":
            parts.append(_fake_quant(chunk, 0))
        else:
            parts.append(_fake_quant(chunk, 1))
    return np.concatenate(parts, axis=1)


def layer_candidates(
    bank: Dict[str, np.ndarray],
    ranks: Sequence[int],
    dtypes: Sequence[str] = ("fp32", "int8"),
    drop_experts: Tuple[int, ...] = (),
    center: str = "wb",
    barycenter_iters: int = 10,
    ot_solver: str = "exact",
    seed: int = 0,
) -> List[PlanCandidate]:
    """Score every (rank, dtype) setting for one expert bank.

    The expensive barycenter runs ONCE at the largest candidate rank; each
    smaller rank is a free truncation of the same SVD factors (the leading
    singular directions are nested), so scoring a whole candidate grid
    costs one compression. Errors use the same §5.2 metric as
    LayerCompression.approximation_error; int8 candidates score a
    fake-quantized round trip of center and factors.
    """
    from .compress import compress_bank, design_matrices

    ranks = sorted(set(int(r) for r in ranks))
    if not ranks or ranks[0] < 1:
        raise ValueError(f"candidate ranks must be >= 1, got {ranks!r}")
    for dt in dtypes:
        if dt not in _DTYPE_BYTES:
            raise ValueError(f"unknown candidate store_dtype {dt!r}")
    lc = compress_bank(bank, method="svd", keep_ratio=1.0, center=center,
                       barycenter_iters=barycenter_iters,
                       ot_solver=ot_solver, seed=seed, rank=max(ranks))
    design = design_matrices(bank)
    n, f, dd = design.shape
    kept = [k for k in range(n) if k not in set(drop_experts)]
    aligned = np.stack([design[k][lc.perms[k]] for k in range(n)])
    drop = tuple(sorted(int(e) for e in drop_experts))

    out: List[PlanCandidate] = []
    for dt in dtypes:
        c = (_fake_quant_center(lc.center, lc.segs) if dt == "int8"
             else lc.center)
        # dropped experts are served AS the center: their error term is the
        # full aligned residual against the (possibly quantized) center
        base_err = sum(float(((aligned[k] - c) ** 2).sum())
                       for k in range(n) if k not in kept)
        for r in ranks:
            tot = base_err
            for k in kept:
                u = lc.residuals[k].u[:, :r]
                v = lc.residuals[k].v[:r, :]
                if dt == "int8":
                    u = _fake_quant(u, 0)   # per rank channel over f
                    v = _fake_quant(v, 1)   # per rank channel over dd
                diff = aligned[k] - (c + u @ v)
                tot += float((diff * diff).sum())
            out.append(PlanCandidate(
                recipe=LayerRecipe(rank=r, store_dtype=dt,
                                   drop_experts=drop),
                bytes=recipe_store_bytes(lc.segs, f, len(kept), r, dt,
                                         num_experts=n),
                error=tot / n / f,
            ))
    return out


# ---------------------------------------------------------------------------
# Greedy byte-budget search
# ---------------------------------------------------------------------------


def solve_plan(
    candidates: Sequence[Sequence[PlanCandidate]],
    byte_budget: int,
    start: Optional[Sequence[int]] = None,
) -> List[PlanCandidate]:
    """Allocate one candidate per layer under a total byte budget.

    Greedy knapsack: start from ``start`` (candidate index per layer —
    e.g. the best uniform setting, which makes the result dominate it by
    construction) or from each layer's cheapest candidate, then repeatedly
    apply the single-layer move with the best error reduction per byte
    that still fits the budget. Moves that reduce error at equal or lower
    bytes are taken unconditionally first (ratio = inf). Total error
    strictly decreases every move, so the search terminates.

    Returns the chosen PlanCandidate per layer (same order as
    ``candidates``); the caller maps them back onto full-model recipes.
    """
    if not candidates:
        raise ValueError("solve_plan: no layers to allocate")
    if start is not None:
        if len(start) != len(candidates):
            raise ValueError("solve_plan: start must index every layer")
        choice = [cands[i] for cands, i in zip(candidates, start)]
    else:
        choice = [min(cands, key=lambda c: c.bytes) for cands in candidates]
    total = sum(c.bytes for c in choice)
    floor = sum(min(c.bytes for c in cands) for cands in candidates)
    if floor > byte_budget:
        # raised BEFORE any greedy move: returning the floor choice would
        # hand the caller an over-budget plan that silently violates the
        # contract (serve.py --byte-budget turns this into a clean exit)
        raise ValueError(
            f"budget infeasible, minimum is {floor} bytes: byte budget "
            f"{byte_budget} is below the cheapest per-layer start — "
            f"raise the budget or add smaller candidates")
    if total > byte_budget:  # an over-budget seed falls back to the floor
        choice = [min(cands, key=lambda c: c.bytes) for cands in candidates]
        total = sum(c.bytes for c in choice)

    while True:
        best = None  # (ratio, -derr, layer, cand)
        for li, cands in enumerate(candidates):
            cur = choice[li]
            for cand in cands:
                if cand.error >= cur.error:
                    continue
                dbytes = cand.bytes - cur.bytes
                if total + dbytes > byte_budget:
                    continue
                derr = cur.error - cand.error
                ratio = float("inf") if dbytes <= 0 else derr / dbytes
                key = (ratio, derr)
                if best is None or key > best[0]:
                    best = (key, li, cand)
        if best is None:
            return choice
        _, li, cand = best
        total += cand.bytes - choice[li].bytes
        choice[li] = cand
