"""Free-support Wasserstein barycenter of expert design matrices.

Implements the Cuturi–Doucet (2014) alternating scheme specialised to the
ResMoE setting: all N input distributions are uniform over ``p_I`` rows and
the barycenter support is constrained to ``p_I`` uniform atoms, so

  (i)  the OT step is an exact assignment (permutation) per expert, and
  (ii) the support-update step is the row-wise mean of the permuted design
       matrices:  W_omega[i] = mean_k  W_k[perm_k[i]].

The fixed point of (i)+(ii) solves problem (4) of the paper (Prop 4.1).

Also provides the ablation centers of Table 4:
  * ``average_center``      — mean with identity permutations (Avg).
  * ``reference_center``    — Git-Re-Basin-style: align every expert to a
                              fixed reference expert once, then average.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .ot import ot_permutation, pairwise_sq_dists


@dataclasses.dataclass
class BarycenterResult:
    center: np.ndarray  # [p_I, d_design]
    perms: np.ndarray  # [N, p_I] int64 — center row i matches expert row perms[k][i]
    objective: float  # final mean squared-Frobenius alignment loss, /p_I
    objective_trace: List[float]


def _objective(mats: np.ndarray, center: np.ndarray, perms: np.ndarray) -> float:
    n = mats.shape[0]
    tot = 0.0
    for k in range(n):
        d = mats[k][perms[k]] - center
        tot += float((d * d).sum())
    return tot / n / mats.shape[1]


def wasserstein_barycenter(
    mats: np.ndarray,
    num_iters: int = 10,
    solver: str = "exact",
    init: str = "auto",
    tol: float = 1e-10,
    seed: int = 0,
    sinkhorn_reg: float = 0.01,
    sinkhorn_iters: int = 200,
) -> BarycenterResult:
    """Free-support WB of ``mats`` ([N, p_I, d]) under W2 over rows.

    ``init``: "mean" starts from the unaligned average, "expert" from
    ``mats[0]``, "random" from a random expert, "reference" from the
    single-pass aligned (Git-Re-Basin-style) center.

    "auto" restarts from {mean, reference} and keeps the lower objective —
    the alternating scheme is non-convex, and because each (OT, update)
    round only decreases the objective, starting at a baseline's center
    guarantees the result dominates that baseline (Table 4 ordering by
    construction, not by luck).
    """
    mats = np.asarray(mats, dtype=np.float64)
    n, p_i, _ = mats.shape
    rng = np.random.default_rng(seed)
    if init == "auto":
        cands = [
            wasserstein_barycenter(mats, num_iters, solver, i, tol, seed,
                                   sinkhorn_reg, sinkhorn_iters)
            for i in ("mean", "reference")
        ]
        return min(cands, key=lambda r: r.objective)
    if init == "mean":
        center = mats.mean(axis=0)
    elif init == "expert":
        center = mats[0].copy()
    elif init == "random":
        center = mats[rng.integers(n)].copy()
    elif init == "reference":
        center = reference_center(mats, solver=solver).center
    else:
        raise ValueError(init)

    perms = np.tile(np.arange(p_i, dtype=np.int64), (n, 1))
    trace: List[float] = []
    prev = np.inf
    for _ in range(num_iters):
        # (i) OT step: align each expert to the current center.
        for k in range(n):
            perms[k] = ot_permutation(
                mats[k], center, solver=solver, reg=sinkhorn_reg, iters=sinkhorn_iters
            )
        # (ii) support update: mean of aligned experts.
        center = np.mean([mats[k][perms[k]] for k in range(n)], axis=0)
        obj = _objective(mats, center, perms)
        trace.append(obj)
        if prev - obj < tol * max(1.0, abs(prev)):
            break
        prev = obj
    return BarycenterResult(center=center, perms=perms, objective=trace[-1], objective_trace=trace)


def average_center(mats: np.ndarray) -> BarycenterResult:
    """Plain average, identity permutations (ablation: 'Avg')."""
    mats = np.asarray(mats, dtype=np.float64)
    n, p_i, _ = mats.shape
    center = mats.mean(axis=0)
    perms = np.tile(np.arange(p_i, dtype=np.int64), (n, 1))
    return BarycenterResult(center, perms, _objective(mats, center, perms), [])


def reference_center(mats: np.ndarray, reference: int = 0, solver: str = "exact") -> BarycenterResult:
    """Git-Re-Basin-style center: single-pass alignment to a fixed reference.

    Every expert is aligned (once) to ``mats[reference]``; the center is the
    mean of the aligned experts. Unlike the WB fixed point this never
    re-aligns against the evolving mean, which is why it is dominated by the
    barycenter in objective value (Table 4 of the paper).
    """
    mats = np.asarray(mats, dtype=np.float64)
    n, p_i, _ = mats.shape
    perms = np.empty((n, p_i), dtype=np.int64)
    for k in range(n):
        if k == reference:
            perms[k] = np.arange(p_i)
        else:
            perms[k] = ot_permutation(mats[k], mats[reference], solver=solver)
    center = np.mean([mats[k][perms[k]] for k in range(n)], axis=0)
    return BarycenterResult(center, perms, _objective(mats, center, perms), [])


def barycenter_by_name(name: str, mats: np.ndarray, **kw) -> BarycenterResult:
    if name in ("wb", "wasserstein", "barycenter"):
        return wasserstein_barycenter(mats, **kw)
    if name in ("avg", "average"):
        return average_center(mats)
    if name in ("git", "reference", "rebasin"):
        return reference_center(mats)
    raise ValueError(name)
