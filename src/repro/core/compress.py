"""End-to-end ResMoE compression of expert banks.

An *expert bank* is the stacked parameter dict of one MoE layer:

    {"w1": [N, d, f], ("w3": [N, d, f] when GLU), "w2": [N, f, d],
     optional "b1": [N, f]}

The design matrix of expert k stacks the bottleneck-1 sub-MLP coordinates as
rows (paper Eq. 3 / Appendix B.3):

    W_k = [ w1_k^T | (b1_k) | (w3_k^T) | w2_k ]  in  R^{f x d_design}

Rows are exchangeable, which is exactly the symmetry the Wasserstein
barycenter exploits.  ``b2`` is row-independent and therefore left untouched
(the paper likewise keeps it outside the ensemble).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .barycenter import BarycenterResult, barycenter_by_name, wasserstein_barycenter
from .residual import CompressedResidual, compress_residual

Array = np.ndarray


# ---------------------------------------------------------------------------
# Design matrices
# ---------------------------------------------------------------------------


def bank_design_dims(bank: Dict[str, Array]) -> List[Tuple[str, int]]:
    """Ordered (name, width) segments of the design matrix columns.

    ``bank`` may be stacked ([N, d, f]) or a single expert ([d, f]).
    """
    segs: List[Tuple[str, int]] = []
    d = bank["w1"].shape[-2]
    segs.append(("w1", d))
    if "b1" in bank:
        segs.append(("b1", 1))
    if "w3" in bank:
        segs.append(("w3", d))
        if "b3" in bank:
            segs.append(("b3", 1))
    segs.append(("w2", d))
    return segs


def design_matrices(bank: Dict[str, Array]) -> Array:
    """[N, f, d_design] design matrices for the whole bank."""
    parts = []
    w1 = np.asarray(bank["w1"])  # [N, d, f]
    parts.append(np.swapaxes(w1, 1, 2))  # [N, f, d]
    if "b1" in bank:
        parts.append(np.asarray(bank["b1"])[..., None])
    if "w3" in bank:
        parts.append(np.swapaxes(np.asarray(bank["w3"]), 1, 2))
        if "b3" in bank:
            parts.append(np.asarray(bank["b3"])[..., None])
    parts.append(np.asarray(bank["w2"]))  # [N, f, d]
    return np.concatenate(parts, axis=-1)


def split_design(design: Array, bank_like: Dict[str, Array]) -> Dict[str, Array]:
    """Inverse of :func:`design_matrices` for a single design matrix [f, dd].

    Returns weights in model layout ({"w1": [d, f], ...}).
    """
    segs = bank_design_dims(bank_like)
    out: Dict[str, Array] = {}
    col = 0
    for name, width in segs:
        chunk = design[:, col : col + width]
        col += width
        if name in ("w1", "w3"):
            out[name] = np.ascontiguousarray(chunk.T)
        elif name in ("b1", "b3"):
            out[name] = np.ascontiguousarray(chunk[:, 0])
        else:  # w2: rows are already [f, d]
            out[name] = np.ascontiguousarray(chunk)
    return out


# ---------------------------------------------------------------------------
# Layer compression artifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerCompression:
    """Compressed representation of one MoE layer's expert bank."""

    center: Array  # [f, d_design] barycenter design matrix
    residuals: List[CompressedResidual]  # per expert
    perms: Array  # [N, f] — center row i ~ expert row perms[k][i]
    segs: List[Tuple[str, int]]
    method: str
    keep_ratio: float
    barycenter_objective: float

    @property
    def num_experts(self) -> int:
        return len(self.residuals)

    def restored_design(self, k: int) -> Array:
        """\\hat W_k = W_omega + Delta_k  (approximates T_k W_k).

        The residual must agree with the center's shape — a silent slice
        here used to mask malformed stores (e.g. a residual compressed
        against a different layer's design). The single legitimate
        mismatch is the block store, whose BCSR layout zero-pads to tile
        multiples; only that exact padding is stripped.
        """
        r = self.residuals[k]
        dd = r.to_dense()
        p, q = self.center.shape
        if dd.shape != (p, q):
            bm, bn = r.block_shape
            if r.method == "block" and dd.shape == (p + (-p) % bm,
                                                    q + (-q) % bn):
                dd = dd[:p, :q]  # strip the BCSR tile padding
            else:
                raise ValueError(
                    f"residual {k} shape {dd.shape} does not match center "
                    f"shape {(p, q)} (method={r.method!r}); the store is "
                    "malformed — was it compressed against a different "
                    "expert bank?"
                )
        return self.center + dd

    def approximation_error(self, design: Array) -> float:
        """Paper §5.2 metric: mean_k ||T_k W_k - \\hat W_k||_F^2 / p_I."""
        n, p_i, _ = design.shape
        tot = 0.0
        for k in range(n):
            aligned = design[k][self.perms[k]]
            diff = aligned - self.restored_design(k)
            tot += float((diff * diff).sum())
        return tot / n / p_i

    def storage_bytes(self, dtype_bytes: int = 2) -> int:
        n = self.center.size * dtype_bytes
        n += sum(r.storage_bytes(dtype_bytes) for r in self.residuals)
        return n

    def num_params(self) -> int:
        return int(self.center.size) + sum(r.num_params() for r in self.residuals)


def compress_bank(
    bank: Dict[str, Array],
    method: str = "svd",
    keep_ratio: float = 0.25,
    center: str = "wb",
    barycenter_iters: int = 10,
    ot_solver: str = "exact",
    block_shape: Tuple[int, int] = (8, 128),
    seed: int = 0,
    rank: Optional[int] = None,
) -> LayerCompression:
    """Run the full ResMoE pipeline (Algorithm 1) on one expert bank.

    ``rank`` overrides the keep_ratio-derived SVD rank — the per-layer
    compression plans (core/plan.py) use this to allocate rank per layer.
    """
    design = design_matrices(bank)  # [N, f, dd]
    bc: BarycenterResult = barycenter_by_name(
        center,
        design,
        **(
            dict(num_iters=barycenter_iters, solver=ot_solver, seed=seed)
            if center in ("wb", "wasserstein", "barycenter")
            else {}
        ),
    )
    residuals = []
    for k in range(design.shape[0]):
        aligned = design[k][bc.perms[k]]
        delta = aligned - bc.center
        residuals.append(
            compress_residual(delta, method, keep_ratio, block_shape, rank=rank))
    return LayerCompression(
        center=bc.center.astype(np.float32),
        residuals=residuals,
        perms=bc.perms,
        segs=bank_design_dims(bank),
        method=method,
        keep_ratio=keep_ratio,
        barycenter_objective=bc.objective,
    )


def restored_bank(comp: LayerCompression, bank_like: Dict[str, Array]) -> Dict[str, Array]:
    """Materialize the restored expert bank (paper Algorithm 2).

    Output uses the *aligned* row order; this changes nothing functionally
    because simultaneous row/col permutation is an invariance of the expert.
    """
    outs: Dict[str, List[Array]] = {}
    for k in range(comp.num_experts):
        w = split_design(comp.restored_design(k), bank_like)
        for name, arr in w.items():
            outs.setdefault(name, []).append(arr)
    restored = {name: np.stack(arrs) for name, arrs in outs.items()}
    if "b2" in bank_like:  # untouched by ResMoE
        restored["b2"] = np.asarray(bank_like["b2"])
    return restored


# ---------------------------------------------------------------------------
# Factored access for the fused (restore-free) forward path
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FusedLayerParams:
    """Arrays consumed by the fused ResMoE-SVD forward path.

    center_*: barycenter weights in model layout.
    u: [N, f, r]     shared row factor of every segment's correction.
    v_*: [N, r, d]   per-segment column factors (v sliced per segment).
    """

    center: Dict[str, Array]
    u: Array
    v: Dict[str, Array]
    rank: int


def fused_params(comp: LayerCompression, bank_like: Dict[str, Array]) -> FusedLayerParams:
    if comp.method != "svd":
        raise ValueError("fused path requires method='svd'")
    center_w = split_design(comp.center, bank_like)
    us, vs = [], {name: [] for name, _ in comp.segs}
    rank = max(r.u.shape[1] for r in comp.residuals)
    for r in comp.residuals:
        u, v = r.u, r.v
        if u.shape[1] < rank:  # pad ranks to a common static size
            u = np.pad(u, ((0, 0), (0, rank - u.shape[1])))
            v = np.pad(v, ((0, rank - v.shape[0]), (0, 0)))
        us.append(u)
        col = 0
        for name, width in comp.segs:
            vs[name].append(v[:, col : col + width])
            col += width
    return FusedLayerParams(
        center=center_w,
        u=np.stack(us),
        v={k: np.stack(v) for k, v in vs.items()},
        rank=rank,
    )
