"""The paper's comparison methods (Tables 1–4), implemented on design matrices.

Every baseline consumes an expert-bank design tensor [N, f, dd] and returns a
``BaselineResult`` with per-expert approximations ``\\hat W_k`` (virtual — we
keep a callable to avoid materializing N copies when not needed), the
approximation error of §5.2 and a parameter count for the compressed store.

Implemented:
  * ``direct_up``      — unstructured magnitude pruning on each expert (UP).
  * ``direct_wanda``   — Wanda-style |W| * ||x||_2 scoring with calibration
                         column norms (data-dependent; synthetic calibration).
  * ``structured``     — neuron (row) pruning by L2 norm (SP).
  * ``direct_svd``     — truncated SVD per expert.
  * ``merge``          — M-SMoE-style: greedy-pair experts into g groups by
                         design distance, group mean as shared weight.
  * ``merge_aligned``  — Git-Re-Basin-as-merge: group + align-to-ref + mean.
  * ``meo``            — MEO-style: merge all experts of a group by summation
                         with uniform coefficients (no alignment).
  * ``mlp_fusion``     — cluster rows into c centroids (k-means), experts
                         approximated by C^T @ centroids (Appendix A.5).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from .ot import ot_permutation
from .residual import compress_residual, svd_rank_for_ratio

Array = np.ndarray


@dataclasses.dataclass
class BaselineResult:
    name: str
    approx: Array  # [N, f, dd] approximated (aligned) design matrices
    perms: Array  # [N, f] alignment used in the error metric (identity if none)
    num_params: int

    def approximation_error(self, design: Array) -> float:
        n, p_i, _ = design.shape
        tot = 0.0
        for k in range(n):
            diff = design[k][self.perms[k]] - self.approx[k]
            tot += float((diff * diff).sum())
        return tot / n / p_i


def _identity_perms(n: int, p_i: int) -> Array:
    return np.tile(np.arange(p_i, dtype=np.int64), (n, 1))


# ---------------------------------------------------------------------------


def direct_up(design: Array, keep_ratio: float) -> BaselineResult:
    n, p_i, dd = design.shape
    approx = np.empty_like(design, dtype=np.float32)
    params = 0
    for k in range(n):
        c = compress_residual(design[k], "up", keep_ratio)
        approx[k] = c.to_dense()
        params += c.num_params()
    return BaselineResult("UP", approx, _identity_perms(n, p_i), params)


def direct_wanda(design: Array, keep_ratio: float, col_norms: Optional[Array] = None,
                 seed: int = 0) -> BaselineResult:
    """Wanda scoring |W_ij| * ||x_j||_2 with (synthetic) calibration norms.

    ``col_norms``: per-column activation norms [dd]. If None, sampled from a
    lognormal — this mirrors Wanda's data dependence without shipping C4.
    """
    n, p_i, dd = design.shape
    if col_norms is None:
        rng = np.random.default_rng(seed)
        col_norms = rng.lognormal(0.0, 0.5, size=(dd,)).astype(np.float64)
    approx = np.empty_like(design, dtype=np.float32)
    params = 0
    k_keep = max(1, int(round(keep_ratio * p_i * dd)))
    for k in range(n):
        score = np.abs(design[k]) * col_norms[None, :]
        thresh = np.partition(score.ravel(), score.size - k_keep)[score.size - k_keep]
        mask = score >= thresh
        approx[k] = np.where(mask, design[k], 0.0)
        params += int(mask.sum())
    return BaselineResult("Wanda", approx, _identity_perms(n, p_i), params)


def structured(design: Array, keep_ratio: float) -> BaselineResult:
    """SP: keep top rows (neurons / bottleneck-1 sub-MLPs) by L2 norm."""
    n, p_i, dd = design.shape
    keep = max(1, int(round(keep_ratio * p_i)))
    approx = np.zeros_like(design, dtype=np.float32)
    for k in range(n):
        norms = (design[k].astype(np.float64) ** 2).sum(-1)
        idx = np.argsort(-norms, kind="stable")[:keep]
        approx[k][idx] = design[k][idx]
    return BaselineResult("SP", approx, _identity_perms(n, p_i), n * keep * dd)


def direct_svd(design: Array, keep_ratio: float) -> BaselineResult:
    n, p_i, dd = design.shape
    approx = np.empty_like(design, dtype=np.float32)
    params = 0
    for k in range(n):
        c = compress_residual(design[k], "svd", keep_ratio)
        approx[k] = c.to_dense()
        params += c.num_params()
    return BaselineResult("SVD", approx, _identity_perms(n, p_i), params)


# ---------------------------------------------------------------------------
# Merging family
# ---------------------------------------------------------------------------


def _greedy_groups(design: Array, num_groups: int) -> List[List[int]]:
    """Greedy pairing by Frobenius distance between design matrices."""
    n = design.shape[0]
    flat = design.reshape(n, -1).astype(np.float64)
    d2 = ((flat[:, None, :] - flat[None, :, :]) ** 2).sum(-1)
    unassigned = list(range(n))
    groups: List[List[int]] = [[] for _ in range(num_groups)]
    # seed groups with the mutually-farthest experts
    seeds = [unassigned.pop(0)]
    while len(seeds) < num_groups:
        far = max(unassigned, key=lambda j: min(d2[j][s] for s in seeds))
        seeds.append(far)
        unassigned.remove(far)
    for gi, s in enumerate(seeds):
        groups[gi].append(s)
    for j in unassigned:
        gi = min(range(num_groups), key=lambda g: min(d2[j][m] for m in groups[g]))
        groups[gi].append(j)
    return groups


def merge(design: Array, num_groups: int = 2) -> BaselineResult:
    """M-SMoE-style (proxy): group + plain mean as every member's weights."""
    n, p_i, dd = design.shape
    approx = np.empty_like(design, dtype=np.float32)
    for g in _greedy_groups(design, num_groups):
        center = design[g].mean(axis=0)
        for k in g:
            approx[k] = center
    return BaselineResult("M-SMoE", approx, _identity_perms(n, p_i), num_groups * p_i * dd)


def merge_aligned(design: Array, num_groups: int = 2) -> BaselineResult:
    """Git-Re-Basin-as-merge: per group, align members to the first, mean."""
    n, p_i, dd = design.shape
    approx = np.empty_like(design, dtype=np.float32)
    perms = _identity_perms(n, p_i)
    for g in _greedy_groups(design, num_groups):
        ref = g[0]
        aligned = [design[ref]]
        local_perms = {ref: np.arange(p_i, dtype=np.int64)}
        for k in g[1:]:
            pk = ot_permutation(design[k], design[ref])
            local_perms[k] = pk
            aligned.append(design[k][pk])
        center = np.mean(aligned, axis=0)
        for k in g:
            approx[k] = center
            perms[k] = local_perms[k]
    return BaselineResult("GitReBasin", approx, perms, num_groups * p_i * dd)


def meo(design: Array, num_groups: int = 2) -> BaselineResult:
    """MEO-style: group merge by (uniform) summation — no alignment, no mean
    rescale distinction matters for the error metric, so use the sum/len."""
    n, p_i, dd = design.shape
    approx = np.empty_like(design, dtype=np.float32)
    groups = _greedy_groups(design, num_groups)
    for g in groups:
        center = design[g].sum(axis=0) / len(g)
        for k in g:
            approx[k] = center
    return BaselineResult("MEO", approx, _identity_perms(n, p_i), num_groups * p_i * dd)


def mlp_fusion(design: Array, keep_ratio: float, iters: int = 25, seed: int = 0) -> BaselineResult:
    """Cluster the p_I rows of each expert into c = keep*p_I centroids.

    Approximation is C^T @ centroids (Appendix A.5)."""
    n, p_i, dd = design.shape
    c = max(1, int(round(keep_ratio * p_i)))
    rng = np.random.default_rng(seed)
    approx = np.empty_like(design, dtype=np.float32)
    params = 0
    for k in range(n):
        x = design[k].astype(np.float64)
        cent = x[rng.choice(p_i, size=c, replace=False)].copy()
        for _ in range(iters):
            d2 = ((x[:, None, :] - cent[None, :, :]) ** 2).sum(-1)
            assign = d2.argmin(axis=1)
            for ci in range(c):
                members = x[assign == ci]
                if len(members):
                    cent[ci] = members.mean(axis=0)
        approx[k] = cent[assign]
        params += c * dd + p_i  # centroids + cluster index
    return BaselineResult("MLPFusion", approx, _identity_perms(n, p_i), params)


# ---------------------------------------------------------------------------


def run_baseline(name: str, design: Array, keep_ratio: float, num_groups: int = 2,
                 seed: int = 0) -> BaselineResult:
    if name == "up":
        return direct_up(design, keep_ratio)
    if name == "wanda":
        return direct_wanda(design, keep_ratio, seed=seed)
    if name == "sp":
        return structured(design, keep_ratio)
    if name == "svd":
        return direct_svd(design, keep_ratio)
    if name == "msmoe":
        return merge(design, num_groups)
    if name == "git":
        return merge_aligned(design, num_groups)
    if name == "meo":
        return meo(design, num_groups)
    if name == "mlp_fusion":
        return mlp_fusion(design, keep_ratio, seed=seed)
    raise ValueError(name)


ALL_BASELINES = ("up", "wanda", "sp", "svd", "msmoe", "git", "meo", "mlp_fusion")
