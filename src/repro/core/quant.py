"""Int8 symmetric per-channel quantization of the ResMoE-SVD store.

The paper notes (§5.4) that the barycenter + residual store is orthogonal
to weight quantization; this module is that composition for the serving
store. Every factor of the compressed store — the barycenter ``center``
segments and the per-expert low-rank ``u``/``v`` factors — is quantized
symmetrically to int8 with one fp32 scale per *channel*:

    q = clip(round(x / s), -127, 127),   s = amax_channel(|x|) / 127

Channel choice is what lets the serving kernels fuse dequantization into
the matmuls they already run (DESIGN.md §9):

  * center ``w1``/``w3`` ([d, f]) and ``w2`` ([f, d]): per OUTPUT channel
    (the last axis) — ``y = (x @ q) * s`` applies the scale to the
    accumulator tile, never to the weight;
  * ``u`` ([E, f, r]) and ``v`` segments ([E, r, d]): per RANK channel
    ([E, r] scales) — every contraction either *produces* the rank axis
    (scale the tiny rank-space vector after the dot) or *consumes* it
    (fold the scale into the rank-space vector before the dot), so the
    int8 factor tiles are only ever cast, never re-scaled elementwise.

Symmetric round-to-nearest gives the analytic elementwise error bound

    |x - s * q| <= s / 2        (per channel; no clipping occurs because
                                 |x| <= 127 s by construction)

checked as a hypothesis property in tests/test_quant.py.

Quantization runs offline on host (numpy); dequantization helpers are jnp
so the non-kernel apply modes can dequantize in-graph.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from ..configs.base import ResMoEConfig

# Serving-store dtypes the pipeline supports end to end (launch/serve.py
# --store-dtype; scripts/check_parity_matrix.py requires a parity test per
# (apply_mode, store_dtype) combination). Source of truth:
# ResMoEConfig.STORE_DTYPES.
STORE_DTYPES = ResMoEConfig.STORE_DTYPES

# Guards all-zero channels: scale stays positive so q = 0 / dequant = 0.
_MIN_AMAX = 1e-30

# Reduction axis per store tensor (the axis amax runs over; the scale
# keeps every OTHER axis). Negative so stacked [L, ...] layouts broadcast.
_STORE_REDUCE_AXES = {"center": -2, "u": -2, "v": -1}


def quantize_int8(x, reduce_axis: int):
    """Symmetric per-channel int8 quantization.

    ``reduce_axis`` is the axis the channel amax reduces over (the axis a
    matmul will contract); the returned fp32 ``scale`` has ``x``'s shape
    with that axis removed.
    """
    x = np.asarray(x, np.float32)
    amax = np.max(np.abs(x), axis=reduce_axis, keepdims=True)
    scale = np.maximum(amax, _MIN_AMAX) / 127.0
    q = np.clip(np.rint(x / scale), -127, 127).astype(np.int8)
    return q, np.squeeze(scale, axis=reduce_axis).astype(np.float32)


def dequantize_int8(q, scale, reduce_axis: int):
    """Inverse of :func:`quantize_int8` (jnp; usable in-graph)."""
    s = jnp.expand_dims(jnp.asarray(scale), reduce_axis)
    return jnp.asarray(q).astype(jnp.float32) * s


def int8_error_bound(scale):
    """Elementwise bound on |x - dequant(quant(x))| per channel.

    Round-to-nearest on |x/s| <= 127 never clips, so the error is at most
    half a quantization step.
    """
    return 0.5 * np.asarray(scale, np.float32)


# ---------------------------------------------------------------------------
# Whole-store helpers (the ffn param dict of one compressed MoE layer)
# ---------------------------------------------------------------------------


def is_quantized_store(params: Dict) -> bool:
    """True for an int8 store (key presence — static under jit)."""
    return "u_scale" in params


def quantize_store(ffn: Dict) -> Dict:
    """Quantize a compressed SVD store's center/u/v to int8 + fp32 scales.

    Input: the ffn param dict holding ``center``/``u``/``v`` (fp32,
    stacked [L, ...] or per-layer). Router / shared / dense branches are
    left untouched. Returns a NEW dict with int8 ``center``/``u``/``v``
    and added ``center_scale``/``u_scale``/``v_scale`` leaves.
    """
    if "u" not in ffn or "center" not in ffn:
        raise ValueError("quantize_store needs an SVD store (center/u/v); "
                         f"got keys {sorted(ffn)}")
    out = dict(ffn)
    cq, cs = {}, {}
    for name, w in ffn["center"].items():
        cq[name], cs[name] = quantize_int8(w, _STORE_REDUCE_AXES["center"])
    out["center"], out["center_scale"] = cq, cs
    out["u"], out["u_scale"] = quantize_int8(ffn["u"], _STORE_REDUCE_AXES["u"])
    vq, vs = {}, {}
    for name, w in ffn["v"].items():
        vq[name], vs[name] = quantize_int8(w, _STORE_REDUCE_AXES["v"])
    out["v"], out["v_scale"] = vq, vs
    return out


def dequantize_store(params: Dict) -> Dict:
    """fp32 ``{center, u, v}`` view of an int8 store (jnp; in-graph).

    Used by the non-kernel apply modes (``restored``/``fused``/
    ``fused_shared``); the grouped/token kernels fuse dequantization
    instead (kernels/resmoe_grouped.py, kernels/resmoe_token.py).
    """
    if not is_quantized_store(params):
        raise ValueError("dequantize_store: not a quantized store")
    center = {
        name: dequantize_int8(q, params["center_scale"][name],
                              _STORE_REDUCE_AXES["center"])
        for name, q in params["center"].items()
    }
    u = dequantize_int8(params["u"], params["u_scale"],
                        _STORE_REDUCE_AXES["u"])
    v = {
        name: dequantize_int8(q, params["v_scale"][name],
                              _STORE_REDUCE_AXES["v"])
        for name, q in params["v"].items()
    }
    return {"center": center, "u": u, "v": v}
