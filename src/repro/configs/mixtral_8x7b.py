"""mixtral-8x7b [moe]: the paper's primary evaluation model.
32L d_model=4096 32H (GQA kv=8) expert d_ff=14336 vocab=32000, 8e top-2.
[arXiv:2401.04088]
"""
from .base import ModelConfig, MoEConfig, ResMoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    attention_type="gqa",
    sliding_window=4096,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    activation="silu",
    glu=True,
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=14336, router_type="softmax",
                  upcycled_init=True),
    resmoe=ResMoEConfig(enabled=True, keep_ratio=0.25, method="up", apply_mode="restored"),
)
