"""musicgen-medium [audio]: 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens, 4 parallel codebooks
(delay pattern handled by the data pipeline; frontend STUB provides frame
embeddings).  [arXiv:2306.05284; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    attention_type="gqa",
    rope_theta=10000.0,
    tie_embeddings=False,
    activation="gelu",
    glu=False,
    frontend="audio",
    num_codebooks=4,
)
