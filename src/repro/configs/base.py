"""Configuration dataclasses for the repro framework.

Every model in the framework is described by a single ``ModelConfig``; the
assigned architectures each provide one instance (src/repro/configs/<id>.py)
plus a reduced preset for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts layer configuration."""

    num_experts: int
    top_k: int
    # d_ff of each routed expert (may differ from the dense d_ff).
    expert_d_ff: int
    # Number of always-on shared experts (DeepSeek-style). Their d_ff equals
    # ``expert_d_ff * num_shared_experts`` stacked as one fused expert.
    num_shared_experts: int = 0
    # Arctic-style: a full dense FFN runs in parallel with the MoE branch.
    dense_residual: bool = False
    # Router style: "softmax" (classic top-k softmax over logits) or
    # "sigmoid" (DeepSeek-v3 sigmoid scoring + normalization over selected).
    router_type: str = "softmax"
    # Normalize the top-k gate values to sum to 1.
    normalize_gates: bool = True
    # Auxiliary load-balance loss coefficient (training).
    aux_loss_coef: float = 0.01
    router_z_loss_coef: float = 0.0
    # Expert capacity factor for the gather-based dispatch (tokens beyond
    # capacity are dropped, Switch-style).
    capacity_factor: float = 1.25
    # Mixtral-style upcycled init: all experts start as (noisy) copies of a
    # single dense FFN — the uniform-weight structure the paper observes
    # makes its barycenter so effective on Mixtral (§5.4).
    upcycled_init: bool = False
    # Minimum per-data-shard token count before the explicit shard_map
    # expert-parallel layer engages (DESIGN.md §6). None = the measured
    # default in models/moe_ep.py (_EP_MIN_LOCAL_TOKENS); tests and
    # benchmarks lower it to force EP on reduced shapes.
    ep_min_local_tokens: Optional[int] = None
    # Maximum token count at which restore-free apply modes on an SVD
    # store take the ragged capacity-free per-token decode path
    # (kernels/resmoe_token.py, DESIGN.md §4.4) instead of the
    # capacity-padded dispatch. None = the analytic default in
    # models/moe.py (_TOKEN_PATH_MAX_TOKENS); 0 disables the automatic
    # switch (apply_mode="fused_token" still forces it).
    token_path_max_tokens: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ResMoEConfig:
    """ResMoE compression configuration (the paper's technique)."""

    enabled: bool = False
    # Fraction of residual parameters retained (paper's main setting: 0.25).
    keep_ratio: float = 0.25
    # "up" = unstructured magnitude pruning; "block" = TPU block-structured
    # pruning (BCSR); "svd" = truncated SVD of the residual.
    method: str = "svd"
    # Which layers to compress (paper: top-N layers). None = all MoE layers.
    first_layer: int = 0
    # Barycenter solver iterations (Cuturi–Doucet outer loop).
    barycenter_iters: int = 10
    # OT solver: "exact" (assignment; scipy JV) or "sinkhorn".
    ot_solver: str = "exact"
    sinkhorn_reg: float = 0.01
    sinkhorn_iters: int = 200
    # Forward path: "restored" (paper Algorithm 2: materialize W_c + delta),
    # "fused" (beyond-paper: never materialize; shared-base + low-rank
    # einsums), "fused_shared" (fused + center products computed once per
    # token before dispatch), "fused_kernel" (fused on the grouped Pallas
    # kernel — one pallas_call per segment over the whole dispatched expert
    # bank; the prefill serving hot path, DESIGN.md §4.2), or "fused_token"
    # (ragged capacity-free per-token path — no dispatch buffer; the decode
    # hot path, DESIGN.md §4.4). The restore-free modes switch to
    # fused_token automatically for small token batches — see
    # MoEConfig.token_path_max_tokens. "center_only" drops the per-expert
    # residuals entirely and runs every expert as the shared barycenter
    # center (gate-weighted, no u/v gathers, no dispatch) — NOT a serving
    # path: it is the drafter of the speculative-decoding layer
    # (launch/spec.py, DESIGN.md §12), whose proposals a full-path
    # verifier accepts or rejects token-by-token.
    apply_mode: str = "restored"
    # Beyond-paper: treat per-layer dense FFNs as the expert population.
    scope: str = "experts"  # "experts" | "cross_layer"
    # Block shape for method="block" (TPU tile-aligned).
    block_shape: Tuple[int, int] = (8, 128)
    # Serving-store dtype: "int8" quantizes center/u/v symmetrically per
    # channel with fp32 scale vectors (core/quant.py, DESIGN.md §9) —
    # ~4x fewer factor HBM bytes, served by the dequant-fused kernels.
    # method="svd" only (dense-delta stores have no factored form).
    store_dtype: str = "fp32"
    # Optional per-layer CompressionPlan (core/plan.py): one LayerRecipe per
    # ORIGINAL model layer overriding rank / store_dtype / dropped experts /
    # dropped blocks. None = the uniform settings above apply everywhere.
    # Typed Any to keep configs import-free of core; validated lazily below
    # and structurally (length, expert bounds) in ModelConfig.__post_init__.
    plan: Optional[Any] = None

    APPLY_MODES = ("restored", "fused", "fused_shared", "fused_kernel",
                   "fused_token", "center_only")
    STORE_DTYPES = ("fp32", "int8")

    def __post_init__(self):
        if self.apply_mode not in self.APPLY_MODES:
            raise ValueError(
                f"unknown resmoe apply_mode {self.apply_mode!r}; "
                f"expected one of {self.APPLY_MODES}"
            )
        if self.store_dtype not in self.STORE_DTYPES:
            raise ValueError(
                f"unknown resmoe store_dtype {self.store_dtype!r}; "
                f"expected one of {self.STORE_DTYPES}"
            )
        if not (0.0 < self.keep_ratio <= 1.0):
            raise ValueError(
                f"resmoe keep_ratio must be in (0, 1], got "
                f"{self.keep_ratio!r} — 0 keeps no residual (use "
                "apply_mode='center_only' for that) and >1 would grow "
                "the store"
            )
        if self.plan is not None:
            # lazy import: configs must stay importable without core (the
            # core package itself imports this module)
            from ..core.plan import CompressionPlan

            if not isinstance(self.plan, CompressionPlan):
                raise TypeError(
                    f"resmoe plan must be a core.plan.CompressionPlan, "
                    f"got {type(self.plan).__name__}"
                )


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One per assigned architecture."""

    name: str
    family: str  # "dense" | "moe" | "hybrid" | "vlm" | "audio" | "ssm"
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    # Attention pattern -------------------------------------------------
    attention_type: str = "gqa"  # "gqa" | "mla" | "none"
    # sliding-window layers: every layer whose (index % local_global_ratio+1)
    # != local_global_ratio is local. 0 = all global.
    sliding_window: int = 0
    local_global_ratio: int = 0  # e.g. gemma3: 5 local : 1 global
    # MLA (DeepSeek-v3) dims --------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # Recurrence (hybrid / ssm) ------------------------------------------
    recurrent_type: str = "none"  # "rglru" | "rwkv6"
    # pattern period for hybrid: e.g. recurrentgemma = 3 (2 recurrent, 1 attn)
    recurrent_pattern: int = 0
    lru_width: int = 0
    # MoE ------------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    moe_every: int = 1  # apply MoE every k-th layer (1 = all layers)
    moe_first_layer: int = 0  # deepseek: first layer(s) dense
    # Modality frontend stub ----------------------------------------------
    frontend: str = "none"  # "none" | "vision" | "audio"
    num_prefix_embeddings: int = 0  # vision patches prepended to text
    num_codebooks: int = 1  # musicgen: parallel codebook streams
    # Misc -----------------------------------------------------------------
    activation: str = "silu"  # "silu" | "gelu" | "relu"
    glu: bool = True  # gated FFN (SwiGLU / GeGLU)
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0
    dtype: str = "bfloat16"
    # ResMoE ----------------------------------------------------------------
    resmoe: ResMoEConfig = dataclasses.field(default_factory=ResMoEConfig)
    # Sharding / training knobs ---------------------------------------------
    remat_policy: str = "nothing_saveable"  # "none"|"nothing_saveable"|"dots"
    scan_layers: bool = True
    optimizer: str = "adamw"  # "adamw" | "adafactor"
    # Sub-quadratic? (controls long_500k applicability)
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.resmoe.enabled and self.resmoe.method == "svd" and self.is_moe:
            # the derived SVD rank of the residual [f, d_design] must be at
            # least 1 — catch a too-small keep_ratio here with a clear error
            # instead of deep inside core/residual.py
            f = self.moe.expert_d_ff
            dd = (3 * self.d_model + 2) if self.glu else (2 * self.d_model + 1)
            derived = int(round(self.resmoe.keep_ratio * f * dd / (f + dd)))
            if derived < 1:
                raise ValueError(
                    f"resmoe keep_ratio={self.resmoe.keep_ratio} derives SVD "
                    f"rank {derived} (< 1) for the [{f}, {dd}] residual of "
                    f"{self.name!r} — raise keep_ratio to at least "
                    f"{(f + dd) / (2 * f * dd):.6f}"
                )
        if self.resmoe.plan is not None:
            plan = self.resmoe.plan
            plan.validate(
                self.num_layers,
                self.moe.num_experts if self.is_moe else None,
            )
            for i, rec in enumerate(plan.recipes):
                is_moe_layer = (
                    self.is_moe
                    and i >= self.moe_first_layer
                    and ((i - self.moe_first_layer) % self.moe_every == 0)
                )
                if not is_moe_layer and not (rec.is_default or rec.drop_block):
                    raise ValueError(
                        f"plan layer {i} of {self.name!r} sets MoE "
                        f"compression options ({rec!r}) but layer {i} is "
                        "not a MoE layer — only drop_block applies there"
                    )

    # -- derived quantities -------------------------------------------------

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def num_params(self) -> int:
        """Total parameter count (analytic)."""
        return _count_params(self, active_only=False)

    def num_active_params(self) -> int:
        """Parameters activated per token (MoE top-k accounting)."""
        return _count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Parameter counting
# ---------------------------------------------------------------------------


def _attention_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    if cfg.attention_type == "mla":
        # DeepSeek-v3 MLA: q down/up, kv down/up, rope embeds, out proj.
        qh = cfg.qk_rope_head_dim + cfg.qk_nope_head_dim
        n = 0
        if cfg.q_lora_rank > 0:
            n += d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.num_heads * qh
        else:
            n += d * cfg.num_heads * qh
        n += d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
        n += cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)
        n += cfg.num_heads * cfg.v_head_dim * d
        return n
    if cfg.attention_type == "none":
        return 0
    hd = cfg.head_dim
    q = d * cfg.num_heads * hd
    kv = 2 * d * cfg.num_kv_heads * hd
    o = cfg.num_heads * hd * d
    return q + kv + o


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    mats = 3 if cfg.glu else 2
    return mats * cfg.d_model * d_ff


def _recurrent_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    if cfg.recurrent_type == "rglru":
        w = cfg.lru_width or d
        # linear in/out + gates (input & recurrence) + diagonal decay params
        return 2 * d * w + 2 * w * w // 1 + 2 * w
    if cfg.recurrent_type == "rwkv6":
        # time-mix: r,k,v,g,o projections + decay/bonus + token-shift lora
        return 5 * d * d + 2 * d + 6 * d * 64
    return 0


def _layer_params(cfg: ModelConfig, layer_idx: int, active_only: bool) -> int:
    n = 2 * cfg.d_model  # 2 norms
    # mixer
    if cfg.recurrent_type != "none" and cfg.recurrent_pattern:
        is_attn = (layer_idx % cfg.recurrent_pattern) == (cfg.recurrent_pattern - 1)
    elif cfg.recurrent_type != "none":
        is_attn = False
    else:
        is_attn = True
    if is_attn and cfg.attention_type != "none":
        n += _attention_params(cfg)
    elif cfg.recurrent_type != "none":
        n += _recurrent_params(cfg)
    # ffn / moe
    is_moe_layer = (
        cfg.is_moe
        and layer_idx >= cfg.moe_first_layer
        and ((layer_idx - cfg.moe_first_layer) % cfg.moe_every == 0)
    )
    if is_moe_layer:
        m = cfg.moe
        router = cfg.d_model * m.num_experts
        n += router
        e = _ffn_params(cfg, m.expert_d_ff)
        if active_only:
            n += m.top_k * e
        else:
            n += m.num_experts * e
        if m.num_shared_experts:
            n += _ffn_params(cfg, m.expert_d_ff * m.num_shared_experts)
        if m.dense_residual:
            n += _ffn_params(cfg, cfg.d_ff)
    else:
        n += _ffn_params(cfg, cfg.d_ff)
    return n


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    n = cfg.vocab_size * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model * (cfg.num_codebooks if cfg.num_codebooks > 1 else 1)
    n += cfg.d_model  # final norm
    for i in range(cfg.num_layers):
        n += _layer_params(cfg, i, active_only)
    return n
