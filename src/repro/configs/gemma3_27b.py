"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global, 128k context.
[hf:google/gemma-3-1b-pt scaled per assignment; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    attention_type="gqa",
    sliding_window=1024,
    local_global_ratio=5,  # 5 local : 1 global
    rope_theta=1_000_000.0,  # global layers; local layers use 10k
    tie_embeddings=True,
    activation="gelu",
    glu=True,
    optimizer="adafactor",
    remat_policy="nothing_saveable",
)
