"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP vision frontend (STUB: input_specs provides
precomputed patch embeddings) + gemma decoder backbone.
[arXiv:2407.07726; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    attention_type="gqa",
    rope_theta=10000.0,
    tie_embeddings=True,
    activation="gelu",
    glu=True,
    frontend="vision",
    num_prefix_embeddings=256,  # 224px / 14 patch -> 256 tokens
)
