"""switch-base-8 (decoder-only analog) [moe]: the paper's second model.

The original Switch Transformer is a T5 encoder-decoder; our framework is
decoder-only, so this config keeps Switch's layer/expert/dff geometry on a
causal backbone (every other layer MoE, top-1 routing, ReLU non-GLU experts,
as in Switch).  Used by the paper-table benchmarks, not by the assigned
dry-run grid.
"""
from .base import ModelConfig, MoEConfig, ResMoEConfig

CONFIG = ModelConfig(
    name="switch-base-8",
    family="moe",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=32128,
    attention_type="gqa",
    tie_embeddings=True,
    activation="relu",
    glu=False,
    moe=MoEConfig(num_experts=8, top_k=1, expert_d_ff=3072, router_type="softmax",
                  capacity_factor=2.0),
    moe_every=2,
    moe_first_layer=1,
    resmoe=ResMoEConfig(enabled=True, keep_ratio=0.25, method="up", apply_mode="restored"),
)
