"""deepseek-v3-671b [moe]: 61L d_model=7168 128H (MLA) expert d_ff=2048
vocab=129280, MoE 1 shared + 256 routed top-8, sigmoid router, first 3
layers dense (d_ff=18432).  MTP head omitted (see DESIGN.md).
[arXiv:2412.19437; hf]

Primary ResMoE target (256 fine-grained experts/layer).
"""
from .base import ModelConfig, MoEConfig, ResMoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,  # MLA: per-head latents; kept for bookkeeping
    head_dim=128,
    d_ff=18432,  # dense layers (first 3)
    vocab_size=129280,
    attention_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    rope_theta=10000.0,
    tie_embeddings=False,
    activation="silu",
    glu=True,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        expert_d_ff=2048,
        num_shared_experts=1,
        router_type="sigmoid",
        normalize_gates=True,
        capacity_factor=1.25,
    ),
    moe_first_layer=3,
    resmoe=ResMoEConfig(enabled=True, keep_ratio=0.25, method="svd", apply_mode="fused"),
    optimizer="adafactor",
)
