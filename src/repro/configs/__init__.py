"""Architecture registry: ``get_config(name)`` + reduced smoke presets."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .base import (
    MULTI_POD_MESH,
    SHAPES,
    SINGLE_POD_MESH,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    ResMoEConfig,
    ShapeConfig,
)

from . import (  # noqa: E402
    arctic_480b,
    deepseek_v3_671b,
    gemma3_27b,
    granite_8b,
    llama3_405b,
    mixtral_8x7b,
    musicgen_medium,
    paligemma_3b,
    recurrentgemma_9b,
    rwkv6_1_6b,
    stablelm_12b,
    switch_base_8,
)

# The 10 assigned architectures (dry-run grid) ------------------------------
ASSIGNED: Dict[str, ModelConfig] = {
    "gemma3-27b": gemma3_27b.CONFIG,
    "stablelm-12b": stablelm_12b.CONFIG,
    "granite-8b": granite_8b.CONFIG,
    "llama3-405b": llama3_405b.CONFIG,
    "arctic-480b": arctic_480b.CONFIG,
    "deepseek-v3-671b": deepseek_v3_671b.CONFIG,
    "recurrentgemma-9b": recurrentgemma_9b.CONFIG,
    "paligemma-3b": paligemma_3b.CONFIG,
    "musicgen-medium": musicgen_medium.CONFIG,
    "rwkv6-1.6b": rwkv6_1_6b.CONFIG,
}

# The paper's own models (benchmarks) ---------------------------------------
PAPER: Dict[str, ModelConfig] = {
    "mixtral-8x7b": mixtral_8x7b.CONFIG,
    "switch-base-8": switch_base_8.CONFIG,
}

ALL: Dict[str, ModelConfig] = {**ASSIGNED, **PAPER}


def get_config(name: str) -> ModelConfig:
    if name not in ALL:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALL)}")
    return ALL[name]


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """The assigned shape cells for an architecture.

    ``long_500k`` needs sub-quadratic attention — run only for SSM/hybrid
    archs (see DESIGN.md §7); all archs here are decoder-style so decode
    shapes always apply.
    """
    shapes = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.subquadratic:
        shapes.append(SHAPES["long_500k"])
    return shapes


# ---------------------------------------------------------------------------
# Reduced presets (CPU smoke tests): same structural family, tiny dims.
# ---------------------------------------------------------------------------


def reduced_config(name: str) -> ModelConfig:
    cfg = get_config(name)
    d_model = 128
    heads = 4
    head_dim = 32
    kv = max(1, min(cfg.num_kv_heads, 2))
    if cfg.num_kv_heads == cfg.num_heads:
        kv = heads  # keep MHA archs MHA
    if cfg.num_kv_heads == 1:
        kv = 1
    # keep at least one full pattern period + remainder behaviour
    if cfg.recurrent_type == "rglru":
        layers = 8  # 2 full (rec,rec,attn) patterns + 2 remainder
    elif cfg.local_global_ratio > 0:
        layers = cfg.local_global_ratio + 3  # one period + remainder
    elif cfg.moe_first_layer > 0:
        layers = cfg.moe_first_layer + 2
    else:
        layers = 3
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=128,
            capacity_factor=2.0,
        )
    updates = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=256,
        vocab_size=512,
        moe=moe,
        dtype="float32",
        remat_policy="none",
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        optimizer="adamw",
    )
    if cfg.attention_type == "mla":
        updates.update(q_lora_rank=32, kv_lora_rank=32, qk_rope_head_dim=16,
                       qk_nope_head_dim=32, v_head_dim=32)
    if cfg.recurrent_type == "rglru":
        updates.update(lru_width=d_model)
    if cfg.recurrent_type == "rwkv6":
        updates.update(num_heads=d_model // 64, num_kv_heads=d_model // 64, head_dim=64)
    if cfg.frontend == "vision":
        updates.update(num_prefix_embeddings=8)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **updates)


__all__ = [
    "ASSIGNED",
    "PAPER",
    "ALL",
    "SHAPES",
    "SINGLE_POD_MESH",
    "MULTI_POD_MESH",
    "MeshConfig",
    "ModelConfig",
    "MoEConfig",
    "ResMoEConfig",
    "ShapeConfig",
    "get_config",
    "applicable_shapes",
    "reduced_config",
]
