"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention (window 2048), pattern
(rec, rec, attn).  [arXiv:2402.19427; unverified]

Sub-quadratic: bounded state => long_500k applies.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    attention_type="gqa",
    sliding_window=2048,
    recurrent_type="rglru",
    recurrent_pattern=3,  # rec, rec, attn
    lru_width=4096,
    rope_theta=10000.0,
    tie_embeddings=True,
    activation="gelu",
    glu=True,
    subquadratic=True,
    optimizer="adafactor",
)
