"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — Finch: data-dependent decay linear attention.
[arXiv:2404.05892; unverified]

Sub-quadratic: O(1) state => long_500k applies.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,  # d_model / 64 wkv heads
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    attention_type="none",
    recurrent_type="rwkv6",
    tie_embeddings=False,
    activation="relu2",
    glu=False,
    subquadratic=True,
)
