"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual branch.  [hf:Snowflake/snowflake-arctic-base; hf]

ResMoE target architecture (128 experts/layer).
"""
from .base import ModelConfig, MoEConfig, ResMoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    attention_type="gqa",
    rope_theta=10000.0,
    tie_embeddings=False,
    activation="silu",
    glu=True,
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        expert_d_ff=4864,
        dense_residual=True,
        router_type="softmax",
        capacity_factor=1.25,
    ),
    resmoe=ResMoEConfig(enabled=True, keep_ratio=0.25, method="svd", apply_mode="fused"),
    optimizer="adafactor",
)
