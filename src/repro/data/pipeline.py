"""Data pipeline: deterministic, shardable, prefetching.

Production posture: every host constructs the same logical stream and slices
its own rows (``host_index``/``num_hosts``); a background thread prefetches
batches so step N+1's data is ready while step N computes.  The synthetic
source is a seeded Markov-ish token generator (learnable structure, so small
training runs show real loss curves); a file-backed token source can be
swapped in via ``DataConfig.token_file`` (memory-mapped .npy of uint16/32).

Determinism: batch ``i`` depends only on (seed, i, host slicing) — restarts
resume mid-stream from the step counter alone, which is what the
fault-tolerant train loop relies on after a crash.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0
    token_file: Optional[str] = None
    num_codebooks: int = 1
    frontend: str = "none"  # "none" | "vision" | "audio"
    d_model: int = 0  # for frontend embedding stubs
    num_prefix: int = 0
    prefetch: int = 2


class SyntheticLMDataset:
    """Deterministic synthetic LM stream with learnable n-gram structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError("global_batch must divide among hosts")
        self.local_batch = cfg.global_batch // cfg.num_hosts
        self._file_tokens = None
        if cfg.token_file:
            self._file_tokens = np.load(cfg.token_file, mmap_mode="r")
        # fixed random transition structure (same on every host)
        rng = np.random.default_rng(cfg.seed)
        self._mix = rng.integers(1, cfg.vocab_size - 1, size=(257,), dtype=np.int64)

    # -- batch construction ---------------------------------------------------

    def _tokens_for(self, index: int) -> np.ndarray:
        c = self.cfg
        b, s = self.local_batch, c.seq_len
        if self._file_tokens is not None:
            total = self._file_tokens.shape[0] - (s + 1)
            rng = np.random.default_rng((c.seed, index, c.host_index))
            starts = rng.integers(0, total, size=(b,))
            return np.stack([self._file_tokens[st : st + s + 1] for st in starts]).astype(
                np.int32
            )
        # synthetic: x_{t+1} = f(x_t) with noise — learnable by a tiny LM
        rng = np.random.default_rng((c.seed, index, c.host_index))
        toks = np.empty((b, s + 1), np.int64)
        toks[:, 0] = rng.integers(0, c.vocab_size, size=(b,))
        noise = rng.random((b, s))
        jumps = rng.integers(0, c.vocab_size, size=(b, s))
        for t in range(s):
            nxt = self._mix[toks[:, t] % 257] % self.cfg.vocab_size
            toks[:, t + 1] = np.where(noise[:, t] < 0.85, nxt, jumps[:, t])
        return toks.astype(np.int32)

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        toks = self._tokens_for(index)
        rng = np.random.default_rng((c.seed, index, c.host_index, 7))
        if c.frontend == "vision":
            st = c.seq_len - c.num_prefix
            return {
                "patch_embeddings": rng.normal(
                    size=(self.local_batch, c.num_prefix, c.d_model)
                ).astype(np.float32),
                "tokens": toks[:, :st],
                "labels": toks[:, 1 : st + 1],
            }
        if c.frontend == "audio":
            k = c.num_codebooks
            labels = np.stack(
                [np.roll(toks[:, 1:], -i, axis=1) % c.vocab_size for i in range(k)],
                axis=-1,
            )
            return {
                "frame_embeddings": rng.normal(
                    size=(self.local_batch, c.seq_len, c.d_model)
                ).astype(np.float32),
                "labels": labels.astype(np.int32),
            }
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # -- iteration with prefetch ----------------------------------------------

    def iterate(self, start_index: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        c = self.cfg
        q: "queue.Queue" = queue.Queue(maxsize=max(1, c.prefetch))
        stop = threading.Event()

        def producer():
            i = start_index
            while not stop.is_set():
                try:
                    q.put(self.batch(i), timeout=0.5)
                    i += 1
                except queue.Full:
                    continue

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def make_pipeline(model_cfg, seq_len: int, global_batch: int, seed: int = 0,
                  num_hosts: int = 1, host_index: int = 0,
                  token_file: Optional[str] = None) -> SyntheticLMDataset:
    return SyntheticLMDataset(
        DataConfig(
            vocab_size=model_cfg.vocab_size,
            seq_len=seq_len,
            global_batch=global_batch,
            seed=seed,
            num_hosts=num_hosts,
            host_index=host_index,
            token_file=token_file,
            num_codebooks=model_cfg.num_codebooks,
            frontend=model_cfg.frontend,
            d_model=model_cfg.d_model,
            num_prefix=model_cfg.num_prefix_embeddings,
        )
    )
