"""Pallas TPU kernel: chunked RWKV6 (wkv) recurrence.

The roofline analysis (benchmarks/roofline, DESIGN.md §8) shows rwkv6 train/prefill
memory terms dominated by per-timestep state traffic: the lax.scan lowering
reads+writes the [H, hd, hd] state from HBM every token.  This kernel keeps
the state resident in VMEM across a whole sequence chunk — state HBM
traffic drops by the chunk length (e.g. 512x).

Grid: (batch*heads,).  Each program owns one head's state and walks the
time dimension with a fori_loop over VMEM-resident r/k/v/w blocks:

    y_t = r_t @ (S + u * (k_t v_t^T));   S <- diag(w_t) S + k_t v_t^T

Shapes per program: r/k/v/w [T, hd]; state scratch [hd, hd] f32.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, s_scr):
    s_scr[...] = s0_ref[0]
    t_len = r_ref.shape[1]

    def step(t, _):
        rt = r_ref[0, t, :]  # [hd]
        kt = k_ref[0, t, :]
        vt = v_ref[0, t, :]
        wt = w_ref[0, t, :]
        kv = kt[:, None] * vt[None, :]  # [hd, hd]
        s = s_scr[...]
        y = jnp.sum(rt[:, None] * (s + u_ref[0][:, None] * kv), axis=0)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        s_scr[...] = wt[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, t_len, step, 0)
    sT_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6_chunk(
    r: jnp.ndarray,  # [BH, T, hd]
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,  # decay in (0, 1)
    u: jnp.ndarray,  # [BH, hd] bonus
    s0: jnp.ndarray,  # [BH, hd, hd] incoming state
    *,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [BH, T, hd], s_final [BH, hd, hd])."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bh, t, hd = r.shape
    grid = (bh,)
    y, s_fin = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, hd), lambda i: (i, 0)),
            pl.BlockSpec((1, hd, hd), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_fin


def wkv6_ref(r, k, v, w, u, s0):
    """Pure-jnp oracle (same math as models.recurrent._wkv6_scan)."""
    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = kt[:, :, None] * vt[:, None, :]
        y = jnp.sum(rt[:, :, None] * (s + u[:, :, None] * kv), axis=1)
        s = wt[:, :, None] * s + kv
        return s, y

    xs = tuple(x.swapaxes(0, 1) for x in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), s_fin
