"""Pallas TPU kernel: grouped restore-free ResMoE-SVD expert-bank matmul.

Extends resmoe_lowrank.py from one expert to the *entire dispatched bank*:

    y[e] = xg[e] @ (W + A[e] @ B[e])        e = 0..E-1

where ``W`` ([K, N]) is the expert-independent barycenter segment shared by
every expert and ``A``/``B`` ([E, K, R] / [E, R, N]) are the per-expert
low-rank residual factors — the exact math of moe.py's ``fused`` path, but
in ONE ``pallas_call`` instead of E-strided einsums over the whole
[E, C, d] dispatch buffer (DESIGN.md §4.2).

Grid: (C/bm, N/bn, E, K/bk) — k innermost, experts *inside* the (m, n)
tile loops.  Per (e, m, n) pass the kernel follows the single-expert
two-matmul structure: accumulate the shared-center partial product and the
low-rank projection t = x @ A[e] in VMEM scratch (f32), flush
``acc + t @ B_tile`` on the last k step.  Because the W block's index map
is expert-independent, whenever the (padded) contraction fits one k block
(the default block picker prefers this while the working set fits VMEM)
consecutive expert steps map W to the SAME block and Pallas elides the
refetch: the center tile streams HBM->VMEM once per (m, n) tile instead of
E times — the property that keeps the restore-free bank at dense-expert
arithmetic intensity.  R is padded to a lane multiple and kept whole in
VMEM (ResMoE ranks are small: keep_ratio * K*N/(K+N)).

Under expert parallelism the kernel is invoked PER SHARD on the local
expert slice (E_loc = E/|model| experts) inside the shard_map region of
models/moe_ep.py — ``W`` is the replicated center, ``A``/``B`` the local
slices of the sharded factors, and nothing here changes: the grid simply
runs E_loc expert steps instead of E (DESIGN.md §6).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Per-call VMEM working-set budget for the default block picker. Real TPUs
# have ~16MB/core; leave headroom for Pallas double-buffering (accounted
# below) and the output buffer.
_VMEM_BUDGET = 10 * 1024 * 1024


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, t_ref, *, n_k: int):
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    x = x_ref[0]
    acc_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    t_ref[...] += jnp.dot(x, a_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _flush():
        lowrank = jnp.dot(
            t_ref[...].astype(b_ref.dtype), b_ref[0],
            preferred_element_type=jnp.float32,
        )
        o_ref[0] = (acc_ref[...] + lowrank).astype(o_ref.dtype)


def _pick_bk(kp: int, bm: int, bn: int, rp: int, itemsize: int,
             w_itemsize: Optional[int] = None) -> int:
    """Largest MXU-aligned k block whose working set fits the VMEM budget.

    Prefers bk == kp (single k step): that is what lets Pallas reuse the
    shared center tile across the expert grid axis. ``w_itemsize``
    overrides the weight-operand itemsize (1 for the int8 store — the
    smaller tiles make a single k block fit at shapes where fp32 cannot).
    """
    wi = itemsize if w_itemsize is None else w_itemsize

    def footprint(bk: int) -> int:
        x_blk = bm * bk
        w_blks = bk * bn + bk * rp + rp * bn  # w, a, b
        return (2 * (itemsize * x_blk + wi * w_blks)
                + 4 * (bm * bn + bm * rp) + itemsize * bm * bn)

    if footprint(kp) <= _VMEM_BUDGET:
        return kp
    bk = 1024
    while bk > 128 and footprint(bk) > _VMEM_BUDGET:
        bk //= 2
    return bk


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype")
)
def grouped_lowrank_matmul(
    xg: jnp.ndarray,  # [E, C, K] dispatched tokens (C = per-expert capacity)
    w: jnp.ndarray,  # [K, N]    shared barycenter segment
    a: jnp.ndarray,  # [E, K, R] per-expert residual row factor
    b: jnp.ndarray,  # [E, R, N] per-expert residual col factor
    *,
    bm: int = 128,
    bn: int = 128,
    bk: Optional[int] = None,
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> jnp.ndarray:
    """y[e] = xg[e] @ (w + a[e] @ b[e]) for the whole expert bank."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    e, c, k = xg.shape
    kk, n = w.shape
    ee, ka, r = a.shape
    assert kk == k and ee == e and ka == k and b.shape == (e, r, n), (
        xg.shape, w.shape, a.shape, b.shape)
    out_dtype = out_dtype or xg.dtype

    # shrink bm to the (sublane-aligned) capacity — decode-sized banks would
    # otherwise pad C=8 up to 128 rows of zeros per expert
    sub = 16 if jnp.dtype(xg.dtype).itemsize == 2 else 8
    bm = min(bm, max(sub, -(-c // sub) * sub))
    pr = (-r) % 128
    rp = r + pr
    if bk is None:
        kp0 = k + ((-k) % 128)
        bk = _pick_bk(kp0, bm, bn, rp, jnp.dtype(xg.dtype).itemsize)

    # pad every dim to its block multiple (kernel-internal; sliced on exit)
    pm, pn, pk = (-c) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        xg = jnp.pad(xg, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pk or pr:
        a = jnp.pad(a, ((0, 0), (0, pk), (0, pr)))
    if pr or pn:
        b = jnp.pad(b, ((0, 0), (0, pr), (0, pn)))
    cp, kp = xg.shape[1:]
    np_ = w.shape[1]
    rp = a.shape[2]
    n_k = kp // bk

    grid = (cp // bm, np_ // bn, e, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda i, j, g, s: (g, i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, g, s: (s, j)),
            pl.BlockSpec((1, bk, rp), lambda i, j, g, s: (g, s, 0)),
            pl.BlockSpec((1, rp, bn), lambda i, j, g, s: (g, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda i, j, g, s: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cp, np_), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, rp), jnp.float32),
        ],
        interpret=interpret,
    )(xg, w, a, b)
    return out[:, :c, :n]


# ---------------------------------------------------------------------------
# Dequant-fused variant for the int8 store (DESIGN.md §9)
# ---------------------------------------------------------------------------


def _kernel_q8(x_ref, w_ref, a_ref, b_ref, sw_ref, sab_ref, o_ref,
               acc_ref, t_ref, *, n_k: int):
    """Same grid/BlockSpec structure as :func:`_kernel`, but ``w``/``a``/
    ``b`` stream from HBM as int8 and are dequantized in registers: tiles
    are cast to f32 for the MXU, and the per-channel scales touch only the
    f32 accumulators — ``acc * sw`` (w's output-channel scale) and
    ``t * sab`` (the combined rank-channel scale of a and b) at flush.
    """
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    x = x_ref[0]
    acc_ref[...] += jnp.dot(x, w_ref[...].astype(x.dtype),
                            preferred_element_type=jnp.float32)
    t_ref[...] += jnp.dot(x, a_ref[0].astype(x.dtype),
                          preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _flush():
        t_scaled = t_ref[...] * sab_ref[0]
        lowrank = jnp.dot(
            t_scaled.astype(jnp.float32), b_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        o_ref[0] = (acc_ref[...] * sw_ref[...] + lowrank).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype")
)
def grouped_lowrank_matmul_q8(
    xg: jnp.ndarray,  # [E, C, K] dispatched tokens (fp32/bf16)
    w: jnp.ndarray,  # [K, N]    int8 shared barycenter segment
    sw: jnp.ndarray,  # [N]      fp32 per-output-channel scale of w
    a: jnp.ndarray,  # [E, K, R] int8 per-expert residual row factor
    b: jnp.ndarray,  # [E, R, N] int8 per-expert residual col factor
    sab: jnp.ndarray,  # [E, R]  fp32 combined rank scale (s_a * s_b)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: Optional[int] = None,
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> jnp.ndarray:
    """y[e] = xg[e] @ (deq(w) + deq(a[e]) @ deq(b[e])), dequant fused.

    The identity the scale placement relies on (core/quant.py): with w
    quantized per output channel n and a/b per rank channel r,

        x @ deq(w)            = (x @ w_q) * sw[n]
        (x @ deq(a)) @ deq(b) = ((x @ a_q) * sa * sb) @ b_q

    so the int8 tiles move 4x fewer HBM bytes and are only ever CAST in
    registers — no elementwise rescale of a weight tile anywhere.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    e, c, k = xg.shape
    kk, n = w.shape
    ee, ka, r = a.shape
    assert kk == k and ee == e and ka == k and b.shape == (e, r, n), (
        xg.shape, w.shape, a.shape, b.shape)
    assert sw.shape == (n,) and sab.shape == (e, r), (sw.shape, sab.shape)
    out_dtype = out_dtype or xg.dtype

    sub = 16 if jnp.dtype(xg.dtype).itemsize == 2 else 8
    bm = min(bm, max(sub, -(-c // sub) * sub))
    pr = (-r) % 128
    rp = r + pr
    if bk is None:
        kp0 = k + ((-k) % 128)
        bk = _pick_bk(kp0, bm, bn, rp, jnp.dtype(xg.dtype).itemsize,
                      w_itemsize=1)

    pm, pn, pk = (-c) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        xg = jnp.pad(xg, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pk or pr:
        a = jnp.pad(a, ((0, 0), (0, pk), (0, pr)))
    if pr or pn:
        b = jnp.pad(b, ((0, 0), (0, pr), (0, pn)))
    # padded w columns / t columns are exact zeros, so zero-padded scales
    # contribute nothing
    sw2 = jnp.pad(sw, (0, pn)).astype(jnp.float32)[None, :]  # [1, N_p]
    sab3 = jnp.pad(sab, ((0, 0), (0, pr))).astype(jnp.float32)[:, None, :]
    cp, kp = xg.shape[1:]
    np_ = w.shape[1]
    rp = a.shape[2]
    n_k = kp // bk

    grid = (cp // bm, np_ // bn, e, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel_q8, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda i, j, g, s: (g, i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, g, s: (s, j)),
            pl.BlockSpec((1, bk, rp), lambda i, j, g, s: (g, s, 0)),
            pl.BlockSpec((1, rp, bn), lambda i, j, g, s: (g, 0, j)),
            pl.BlockSpec((1, bn), lambda i, j, g, s: (0, j)),
            pl.BlockSpec((1, 1, rp), lambda i, j, g, s: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda i, j, g, s: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cp, np_), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, rp), jnp.float32),
        ],
        interpret=interpret,
    )(xg, w, a, b, sw2, sab3)
    return out[:, :c, :n]
