"""Pallas TPU kernel: ragged per-token restore-free ResMoE-SVD MoE decode.

The dispatched paths (moe.py ``fused``/``fused_kernel``) route every batch
through a capacity-padded ``[E, C, d]`` buffer — built for prefill, where
thousands of tokens amortize the E-wide buffer construction. A decode step
of the continuous-batching server carries only ``num_slots`` live tokens,
so the same machinery pays for ``E * C`` padded rows (C >= 8) and
capacity-drop semantics to process a handful of real tokens, and the
grouped kernel re-streams the shared center once per *expert* instead of
once per *token tile* (DESIGN.md §4.4).

``token_lowrank_moe`` is the capacity-free alternative for a small token
batch ``[T, d]`` with per-token top-k expert ids and gates:

    y_t = sum_k g_tk * f_{e_tk}(x_t)
    f_e(x) = act(x W1c + (x A1_e) B1_e) [* (x W3c + (x A3_e) B1_e)]
             @ W2c  +  (h u_e) v2_e             (restore-free, per pair)

structured so every shared-center product is computed ONCE per token:

  * segments 1/3: ``base = x @ Wc`` is expert-independent — one dense
    ``[T, d] @ [d, f]`` matmul outside the kernel, gathered per pair by a
    block index map (the grouped path recomputes it per dispatched copy);
  * segment 2: the gate sum distributes over the center,
    ``sum_k g (h_k @ W2c) = (sum_k g h_k) @ W2c``, so the center product
    runs once per token on the gate-weighted ``hbar`` and only the
    low-rank correction ``(h u_e) v2_e`` stays per pair.

The ``pallas_call`` handles exactly the ragged per-pair piece. Grid
``(P, F/bf)`` over the ``P = T*k`` (token, k) pairs sorted by expert id,
f-tile innermost. Scalar-prefetched expert/token ids drive the block index
maps, so each grid step gathers ONLY its pair's low-rank factors — no
``[E, C, d]`` buffer, no capacity drops, no scatter. Because pairs are
sorted, consecutive steps with the same expert map the factor blocks to
the same HBM region and Pallas elides the refetch: the factor traffic is
``min(P, E)`` sets, not ``P``. Per (p, j) step the kernel follows the
two-matmul structure of resmoe_lowrank.py: the rank-space projections
``t1 = x A1_e`` (and ``t3``) are computed on the first f step into VMEM
scratch, each f tile applies ``base + t B1_e`` + activation (+ GLU gate),
and a third scratch accumulates ``t2 += h u_e`` across f tiles, flushed
through ``v2_e`` on the last step.

Duplicate expert ids inside a token's top-k are legal (each pair is
independent); T=1 degenerates to a k-step grid.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Per-step VMEM working-set budget for the default f-tile picker — one
# source of truth with the grouped kernel (~16MB/core minus Pallas
# double-buffering headroom).
from .resmoe_grouped import _VMEM_BUDGET


# contract the LAST dim of both operands: (1, c) x (n, c) -> (1, n).
# Lets the kernel consume the store's native layouts (v [E, r, d],
# u [E, f, r]) with no per-call transpose of the factor bank.
_CONTRACT_LAST = (((1,), (1,)), ((), ()))


def _kernel(eids_ref, tids_ref, xp_ref, base1_ref, *rest, n_f: int,
            glu: bool, activation: str):
    import jax

    from ..models.layers import activation_fn

    if glu:
        (base3_ref, v1_ref, v3_ref, u_ref, v2_ref,
         oh_ref, oy_ref, t1_ref, t3_ref, t2_ref) = rest
    else:
        (v1_ref, u_ref, v2_ref,
         oh_ref, oy_ref, t1_ref, t2_ref) = rest
        base3_ref = v3_ref = t3_ref = None

    j = pl.program_id(1)

    @pl.when(j == 0)
    def _project():
        # rank-space projections of this pair's token: computed once per
        # pair, reused across every f tile
        xrow = xp_ref[...]
        t1_ref[...] = jax.lax.dot_general(
            xrow, v1_ref[0], _CONTRACT_LAST,
            preferred_element_type=jnp.float32)
        if glu:
            t3_ref[...] = jax.lax.dot_general(
                xrow, v3_ref[0], _CONTRACT_LAST,
                preferred_element_type=jnp.float32)
        t2_ref[...] = jnp.zeros_like(t2_ref)

    act = activation_fn(activation)
    u_blk = u_ref[0]  # [bf, rp] — shared by the w1/w3 corrections AND t2
    h = base1_ref[...] + jax.lax.dot_general(
        t1_ref[...].astype(u_blk.dtype), u_blk, _CONTRACT_LAST,
        preferred_element_type=jnp.float32)
    h = act(h)
    if glu:
        h = h * (base3_ref[...] + jax.lax.dot_general(
            t3_ref[...].astype(u_blk.dtype), u_blk, _CONTRACT_LAST,
            preferred_element_type=jnp.float32))
    oh_ref[...] = h.astype(oh_ref.dtype)
    t2_ref[...] += jnp.dot(h.astype(u_blk.dtype), u_blk,
                           preferred_element_type=jnp.float32)

    @pl.when(j == n_f - 1)
    def _flush():
        oy_ref[...] = jnp.dot(
            t2_ref[...].astype(v2_ref.dtype), v2_ref[0],
            preferred_element_type=jnp.float32,
        ).astype(oy_ref.dtype)


def _pick_bf(f: int, dp: int, rp: int, itemsize: int) -> int:
    """Largest lane-aligned f tile whose per-step working set fits VMEM."""

    def footprint(bf: int) -> int:
        # xp, base1/3, v1/v3, u, v2, oh, oy blocks (double-buffered)
        blocks = dp + 2 * bf + 2 * rp * dp + bf * rp + rp * dp + bf + dp
        return 2 * itemsize * blocks + 4 * 3 * rp

    bf = min(512, f + ((-f) % 128))
    while bf > 128 and footprint(bf) > _VMEM_BUDGET:
        bf //= 2
    return bf


@functools.partial(
    jax.jit, static_argnames=("activation", "bf", "interpret", "out_dtype")
)
def token_lowrank_moe(
    x: jnp.ndarray,  # [T, d] live tokens (decode batch)
    expert_ids: jnp.ndarray,  # [T, k] int top-k expert ids per token
    gates: jnp.ndarray,  # [T, k] per-pair combine weights
    center: Dict[str, jnp.ndarray],  # {"w1": [d, f], "w2": [f, d], ("w3")}
    u: jnp.ndarray,  # [E, f, r] per-expert residual row factor
    v: Dict[str, jnp.ndarray],  # {"w1"/"w2"/("w3"): [E, r, d]} col factors
    *,
    activation: str = "silu",
    bf: Optional[int] = None,
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> jnp.ndarray:
    """Capacity-free per-token MoE expert compute on an SVD store.

    Returns the gate-combined expert output ``[T, d]`` — the exact math of
    moe.py's ``fused`` path (kernels/ref.py::token_lowrank_moe_ref is the
    allclose oracle), with no dispatch buffer in between.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t, d = x.shape
    k = expert_ids.shape[1]
    p = t * k
    e, f, r = u.shape
    out_dtype = out_dtype or x.dtype
    glu = "w3" in center

    # sort pairs by expert id: consecutive same-expert grid steps map the
    # factor blocks identically and Pallas elides the refetch
    flat_e = expert_ids.reshape(-1).astype(jnp.int32)
    order = jnp.argsort(flat_e, stable=True)
    eids = flat_e[order]
    tids = (order // k).astype(jnp.int32)
    g = gates.reshape(-1)[order].astype(jnp.float32)

    # shared-center products: once per TOKEN, plain dense matmuls
    xf = x.astype(jnp.float32)
    base1 = xf @ center["w1"].astype(jnp.float32)  # [T, f]
    base3 = xf @ center["w3"].astype(jnp.float32) if glu else None

    # NATIVE store layouts throughout — the kernel contracts the trailing
    # dims in place, so the per-expert factor bank is never transposed (a
    # per-step whole-bank copy the roofline would otherwise have to charge)
    v1 = v["w1"]  # [E, r, d]
    v3 = v["w3"] if glu else None
    v2 = v["w2"]  # [E, r, d]

    itemsize = jnp.dtype(x.dtype).itemsize
    pd, pr = (-d) % 128, (-r) % 128
    dp, rp = d + pd, r + pr
    if bf is None:
        bf = _pick_bf(f, dp, rp, itemsize)
    pf = (-f) % bf
    fp = f + pf

    xq = jnp.pad(x, ((0, 0), (0, pd))) if pd else x
    if pf:
        base1 = jnp.pad(base1, ((0, 0), (0, pf)))
        if glu:
            base3 = jnp.pad(base3, ((0, 0), (0, pf)))
    if pr or pd:
        v1 = jnp.pad(v1, ((0, 0), (0, pr), (0, pd)))
        v2 = jnp.pad(v2, ((0, 0), (0, pr), (0, pd)))
        if glu:
            v3 = jnp.pad(v3, ((0, 0), (0, pr), (0, pd)))
    if pf or pr:
        u = jnp.pad(u, ((0, 0), (0, pf), (0, pr)))
    n_f = fp // bf

    def _e(idx3):
        # factor blocks: gathered by the pair's (scalar-prefetched) expert
        return lambda i, j, eids, tids: idx3(eids[i], j)

    in_specs = [
        # token rows read straight from x by the pair's token id — no
        # pair-gathered [P, d] copy
        pl.BlockSpec((1, dp), lambda i, j, eids, tids: (tids[i], 0)),
        pl.BlockSpec((1, bf), lambda i, j, eids, tids: (tids[i], j)),  # base1
    ]
    operands = [xq, base1.astype(jnp.float32)]
    if glu:
        in_specs.append(
            pl.BlockSpec((1, bf), lambda i, j, eids, tids: (tids[i], j)))
        operands.append(base3.astype(jnp.float32))
    in_specs.append(pl.BlockSpec((1, rp, dp), _e(lambda ei, j: (ei, 0, 0))))
    operands.append(v1)
    if glu:
        in_specs.append(pl.BlockSpec((1, rp, dp), _e(lambda ei, j: (ei, 0, 0))))
        operands.append(v3)
    in_specs += [
        pl.BlockSpec((1, bf, rp), _e(lambda ei, j: (ei, j, 0))),  # u
        pl.BlockSpec((1, rp, dp), _e(lambda ei, j: (ei, 0, 0))),  # v2
    ]
    operands += [u, v2]

    scratch = [pltpu.VMEM((1, rp), jnp.float32)]  # t1
    if glu:
        scratch.append(pltpu.VMEM((1, rp), jnp.float32))  # t3
    scratch.append(pltpu.VMEM((1, rp), jnp.float32))  # t2

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(p, n_f),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, bf), lambda i, j, eids, tids: (i, j)),
            pl.BlockSpec((1, dp), lambda i, j, eids, tids: (i, 0)),
        ],
        scratch_shapes=scratch,
    )
    oh, oy = pl.pallas_call(
        functools.partial(_kernel, n_f=n_f, glu=glu, activation=activation),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((p, fp), jnp.float32),  # per-pair h
            jax.ShapeDtypeStruct((p, dp), jnp.float32),  # per-pair lowrank y
        ],
        interpret=interpret,
    )(eids, tids, *operands)

    # gate-weighted combine: scatter-add over the (tiny) token axis, then
    # the single per-token center product for segment 2
    gh = oh[:, :f] * g[:, None]
    hbar = jnp.zeros((t, f), jnp.float32).at[tids].add(gh)
    ylr = jnp.zeros((t, d), jnp.float32).at[tids].add(oy[:, :d] * g[:, None])
    y = hbar @ center["w2"].astype(jnp.float32) + ylr
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# Dequant-fused variant for the int8 store (DESIGN.md §9)
# ---------------------------------------------------------------------------


def _kernel_q8(eids_ref, tids_ref, xp_ref, base1_ref, *rest, n_f: int,
               glu: bool, activation: str):
    """Same grid/BlockSpec structure as :func:`_kernel`; the per-pair
    low-rank factors stream as int8 and are dequantized in registers —
    tiles are cast to f32 for the MXU and the per-channel scales touch
    only the rank-space vectors: ``t1 = (x · v1_q) * (s_v1 s_u)`` at
    projection time and ``(t2 * (s_u s_v2)) · v2_q`` at flush
    (core/quant.py states the identities).
    """
    import jax

    from ..models.layers import activation_fn

    if glu:
        (base3_ref, v1_ref, v3_ref, u_ref, v2_ref, s1_ref, s3_ref, s2_ref,
         oh_ref, oy_ref, t1_ref, t3_ref, t2_ref) = rest
    else:
        (v1_ref, u_ref, v2_ref, s1_ref, s2_ref,
         oh_ref, oy_ref, t1_ref, t2_ref) = rest
        base3_ref = v3_ref = t3_ref = s3_ref = None

    j = pl.program_id(1)

    @pl.when(j == 0)
    def _project():
        xrow = xp_ref[...]
        t1_ref[...] = jax.lax.dot_general(
            xrow, v1_ref[0].astype(jnp.float32), _CONTRACT_LAST,
            preferred_element_type=jnp.float32) * s1_ref[0]
        if glu:
            t3_ref[...] = jax.lax.dot_general(
                xrow, v3_ref[0].astype(jnp.float32), _CONTRACT_LAST,
                preferred_element_type=jnp.float32) * s3_ref[0]
        t2_ref[...] = jnp.zeros_like(t2_ref)

    act = activation_fn(activation)
    u_blk = u_ref[0].astype(jnp.float32)  # [bf, rp] int8 -> registers
    h = base1_ref[...] + jax.lax.dot_general(
        t1_ref[...], u_blk, _CONTRACT_LAST,
        preferred_element_type=jnp.float32)
    h = act(h)
    if glu:
        h = h * (base3_ref[...] + jax.lax.dot_general(
            t3_ref[...], u_blk, _CONTRACT_LAST,
            preferred_element_type=jnp.float32))
    oh_ref[...] = h.astype(oh_ref.dtype)
    t2_ref[...] += jnp.dot(h, u_blk, preferred_element_type=jnp.float32)

    @pl.when(j == n_f - 1)
    def _flush():
        oy_ref[...] = jnp.dot(
            t2_ref[...] * s2_ref[0], v2_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ).astype(oy_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("activation", "bf", "interpret", "out_dtype")
)
def token_lowrank_moe_q8(
    x: jnp.ndarray,  # [T, d] live tokens (decode batch)
    expert_ids: jnp.ndarray,  # [T, k] int top-k expert ids per token
    gates: jnp.ndarray,  # [T, k] per-pair combine weights
    center: Dict[str, jnp.ndarray],  # int8 {"w1": [d, f], "w2": [f, d], ..}
    center_scale: Dict[str, jnp.ndarray],  # fp32 per-output-channel scales
    u: jnp.ndarray,  # [E, f, r] int8 residual row factor
    u_scale: jnp.ndarray,  # [E, r] fp32 rank-channel scale
    v: Dict[str, jnp.ndarray],  # int8 {"w1"/"w2"/("w3"): [E, r, d]}
    v_scale: Dict[str, jnp.ndarray],  # fp32 {..: [E, r]} rank-channel scales
    *,
    activation: str = "silu",
    bf: Optional[int] = None,
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> jnp.ndarray:
    """Capacity-free per-token MoE on the int8 store, dequant fused.

    Identical structure to :func:`token_lowrank_moe`; the shared-center
    products stay plain dense matmuls with the dequantization folded in as
    a post-matmul column scale (``(x @ w_q) * s_w``), and the ragged
    kernel consumes the int8 factor bank directly — 4x fewer factor HBM
    bytes per gathered expert set.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t, d = x.shape
    k = expert_ids.shape[1]
    p = t * k
    e, f, r = u.shape
    out_dtype = out_dtype or x.dtype
    glu = "w3" in center

    flat_e = expert_ids.reshape(-1).astype(jnp.int32)
    order = jnp.argsort(flat_e, stable=True)
    eids = flat_e[order]
    tids = (order // k).astype(jnp.int32)
    g = gates.reshape(-1)[order].astype(jnp.float32)

    # shared-center products: dequant fused as a post-matmul column scale
    xf = x.astype(jnp.float32)
    base1 = (xf @ center["w1"].astype(jnp.float32)) \
        * center_scale["w1"].astype(jnp.float32)[None, :]
    base3 = ((xf @ center["w3"].astype(jnp.float32))
             * center_scale["w3"].astype(jnp.float32)[None, :]) if glu else None

    v1, v2 = v["w1"], v["w2"]
    v3 = v["w3"] if glu else None
    su = u_scale.astype(jnp.float32)
    s1 = v_scale["w1"].astype(jnp.float32) * su  # [E, r]
    s3 = v_scale["w3"].astype(jnp.float32) * su if glu else None
    s2 = su * v_scale["w2"].astype(jnp.float32)

    itemsize = jnp.dtype(x.dtype).itemsize
    pd, pr = (-d) % 128, (-r) % 128
    dp, rp = d + pd, r + pr
    if bf is None:
        bf = _pick_bf(f, dp, rp, itemsize)
    pf = (-f) % bf
    fp = f + pf

    xq = jnp.pad(x, ((0, 0), (0, pd))) if pd else x
    if pf:
        base1 = jnp.pad(base1, ((0, 0), (0, pf)))
        if glu:
            base3 = jnp.pad(base3, ((0, 0), (0, pf)))
    if pr or pd:
        v1 = jnp.pad(v1, ((0, 0), (0, pr), (0, pd)))
        v2 = jnp.pad(v2, ((0, 0), (0, pr), (0, pd)))
        if glu:
            v3 = jnp.pad(v3, ((0, 0), (0, pr), (0, pd)))
    if pf or pr:
        u = jnp.pad(u, ((0, 0), (0, pf), (0, pr)))
    # zero-padded rank scales: the padded t columns are exact zeros anyway
    if pr:
        s1 = jnp.pad(s1, ((0, 0), (0, pr)))
        s2 = jnp.pad(s2, ((0, 0), (0, pr)))
        if glu:
            s3 = jnp.pad(s3, ((0, 0), (0, pr)))
    s1 = s1[:, None, :]  # [E, 1, rp]
    s2 = s2[:, None, :]
    if glu:
        s3 = s3[:, None, :]
    n_f = fp // bf

    def _e(idx3):
        return lambda i, j, eids, tids: idx3(eids[i], j)

    in_specs = [
        pl.BlockSpec((1, dp), lambda i, j, eids, tids: (tids[i], 0)),
        pl.BlockSpec((1, bf), lambda i, j, eids, tids: (tids[i], j)),  # base1
    ]
    operands = [xq, base1.astype(jnp.float32)]
    if glu:
        in_specs.append(
            pl.BlockSpec((1, bf), lambda i, j, eids, tids: (tids[i], j)))
        operands.append(base3.astype(jnp.float32))
    in_specs.append(pl.BlockSpec((1, rp, dp), _e(lambda ei, j: (ei, 0, 0))))
    operands.append(v1)
    if glu:
        in_specs.append(pl.BlockSpec((1, rp, dp), _e(lambda ei, j: (ei, 0, 0))))
        operands.append(v3)
    in_specs += [
        pl.BlockSpec((1, bf, rp), _e(lambda ei, j: (ei, j, 0))),  # u
        pl.BlockSpec((1, rp, dp), _e(lambda ei, j: (ei, 0, 0))),  # v2
        pl.BlockSpec((1, 1, rp), _e(lambda ei, j: (ei, 0, 0))),   # s1
    ]
    operands += [u, v2, s1]
    if glu:
        in_specs.append(pl.BlockSpec((1, 1, rp), _e(lambda ei, j: (ei, 0, 0))))
        operands.append(s3)
    in_specs.append(pl.BlockSpec((1, 1, rp), _e(lambda ei, j: (ei, 0, 0))))
    operands.append(s2)

    scratch = [pltpu.VMEM((1, rp), jnp.float32)]  # t1
    if glu:
        scratch.append(pltpu.VMEM((1, rp), jnp.float32))  # t3
    scratch.append(pltpu.VMEM((1, rp), jnp.float32))  # t2

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(p, n_f),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, bf), lambda i, j, eids, tids: (i, j)),
            pl.BlockSpec((1, dp), lambda i, j, eids, tids: (i, 0)),
        ],
        scratch_shapes=scratch,
    )
    oh, oy = pl.pallas_call(
        functools.partial(_kernel_q8, n_f=n_f, glu=glu, activation=activation),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((p, fp), jnp.float32),
            jax.ShapeDtypeStruct((p, dp), jnp.float32),
        ],
        interpret=interpret,
    )(eids, tids, *operands)

    gh = oh[:, :f] * g[:, None]
    hbar = jnp.zeros((t, f), jnp.float32).at[tids].add(gh)
    ylr = jnp.zeros((t, d), jnp.float32).at[tids].add(oy[:, :d] * g[:, None])
    y = (hbar @ center["w2"].astype(jnp.float32)) \
        * center_scale["w2"].astype(jnp.float32)[None, :] + ylr
    return y.astype(out_dtype)
