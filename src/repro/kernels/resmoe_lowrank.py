"""Pallas TPU kernel: fused restore-free ResMoE-SVD matmul.

Computes  y = x @ (W + A @ B)  without materializing W + A@B in HBM:

    y[m, n] = sum_k x[m,k] W[k,n]  +  sum_r (sum_k x[m,k] A[k,r]) B[r,n]

Grid (M/bm, N/bn, K/bk), k innermost.  Per (m, n) pass we accumulate both
the dense partial product and the low-rank projection t = x@A in VMEM
scratch (f32), and flush  acc + t @ B_tile  on the last k step.  The MXU
sees two back-to-back matmuls per step; W streams HBM->VMEM exactly once
per (m, n) tile — the memory-bandwidth property that makes the paper's
restore step free on TPU (DESIGN.md §4.2).

Block shapes are MXU-aligned (multiples of 8 x 128); R (the residual rank)
is kept whole in VMEM — ResMoE ranks are small (keep_ratio * K*N/(K+N),
e.g. 736 for a Mixtral expert at 25%).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, t_ref, *, n_k: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    t_ref[...] += jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _flush():
        lowrank = jnp.dot(
            t_ref[...].astype(b_ref.dtype), b_ref[...],
            preferred_element_type=jnp.float32,
        )
        o_ref[...] = (acc_ref[...] + lowrank).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype")
)
def lowrank_restore_matmul(
    x: jnp.ndarray,  # [M, K]
    w: jnp.ndarray,  # [K, N]
    a: jnp.ndarray,  # [K, R]
    b: jnp.ndarray,  # [R, N]
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, k = x.shape
    kk, n = w.shape
    r = a.shape[1]
    assert kk == k and a.shape[0] == k and b.shape == (r, n), (
        x.shape, w.shape, a.shape, b.shape)
    out_dtype = out_dtype or x.dtype

    # pad every dim to its block multiple (kernel-internal; sliced on exit)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    pr = (-r) % 128
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pk or pr:
        a = jnp.pad(a, ((0, pk), (0, pr)))
    if pr or pn:
        b = jnp.pad(b, ((0, pr), (0, pn)))
    mp, kp = x.shape
    np_ = w.shape[1]
    rp = a.shape[1]
    n_k = kp // bk

    grid = (mp // bm, np_ // bn, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bk, rp), lambda i, j, s: (s, 0)),
            pl.BlockSpec((rp, bn), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, rp), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b)
    return out[:m, :n]
