"""Pallas TPU kernel: block-sparse residual matmul (BCSR, scalar prefetch).

TPU adaptation of ResMoE's unstructured-pruning residuals (DESIGN.md §4.1):
residual Delta is pruned at tile granularity and stored as coordinate blocks

    values [nnzb, bk, bn], block_row [nnzb], block_col [nnzb]

The kernel computes  y = x @ Delta  visiting ONLY the surviving blocks.
Blocks are pre-sorted by column tile so that every output tile is visited in
one consecutive run of grid steps — the accumulator tile stays resident in
VMEM across the run and is stored exactly once (Pallas's revisiting rule).
``is_first`` (scalar-prefetched) marks the head of each run so the tile is
initialized rather than accumulated.  Host-side preparation pads the block
list so every output column tile has at least one (possibly zero) block.

Grid: (M/bm, nnzb) — j (the block index) innermost.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(brow_ref, bcol_ref, first_ref, x_ref, v_ref, o_ref):
    j = pl.program_id(1)
    contrib = jnp.dot(x_ref[...], v_ref[0], preferred_element_type=jnp.float32)

    @pl.when(first_ref[j] == 1)
    def _set():
        o_ref[...] = contrib.astype(o_ref.dtype)

    @pl.when(first_ref[j] == 0)
    def _add():
        o_ref[...] = (o_ref[...].astype(jnp.float32) + contrib).astype(o_ref.dtype)


def prepare_bcsr(
    values: np.ndarray,  # [nnzb, bk, bn]
    block_row: np.ndarray,
    block_col: np.ndarray,
    n_col_blocks: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Sort blocks by column tile; pad so every column tile is covered.

    Returns (values, block_row, block_col, is_first) ready for the kernel.
    """
    values = np.asarray(values)
    block_row = np.asarray(block_row, np.int32)
    block_col = np.asarray(block_col, np.int32)
    order = np.argsort(block_col, kind="stable")
    values, block_row, block_col = values[order], block_row[order], block_col[order]
    present = np.zeros(n_col_blocks, bool)
    present[block_col] = True
    missing = np.flatnonzero(~present).astype(np.int32)
    if missing.size:
        pad_vals = np.zeros((missing.size,) + values.shape[1:], values.dtype)
        values = np.concatenate([values, pad_vals])
        block_row = np.concatenate([block_row, np.zeros(missing.size, np.int32)])
        block_col = np.concatenate([block_col, missing])
        order = np.argsort(block_col, kind="stable")
        values, block_row, block_col = values[order], block_row[order], block_col[order]
    is_first = np.ones(len(block_col), np.int32)
    is_first[1:] = (block_col[1:] != block_col[:-1]).astype(np.int32)
    return values, block_row, block_col, is_first


@functools.partial(jax.jit, static_argnames=("n", "bm", "interpret", "out_dtype"))
def block_sparse_matmul(
    x: jnp.ndarray,  # [M, K]
    values: jnp.ndarray,  # [nnzb, bk, bn] (column-sorted, padded)
    block_row: jnp.ndarray,  # [nnzb] int32
    block_col: jnp.ndarray,  # [nnzb] int32
    is_first: jnp.ndarray,  # [nnzb] int32
    *,
    n: int,
    bm: int = 128,
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, k = x.shape
    nnzb, bk, bn = values.shape
    out_dtype = out_dtype or x.dtype
    pm = (-m) % bm
    pk = (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    mp = x.shape[0]
    pn = (-n) % bn
    np_ = n + pn

    grid = (mp // bm, nnzb)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, brow, bcol, first: (i, brow[j])),
                pl.BlockSpec((1, bk, bn), lambda i, j, brow, bcol, first: (j, 0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (bm, bn), lambda i, j, brow, bcol, first: (i, bcol[j])
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=interpret,
    )(block_row, block_col, is_first, x, values)
    return out[:m, :n]
