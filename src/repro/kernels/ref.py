"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def lowrank_restore_matmul_ref(
    x: jnp.ndarray,  # [M, K]
    w: jnp.ndarray,  # [K, N]  barycenter weight
    a: jnp.ndarray,  # [K, R]  residual row factor
    b: jnp.ndarray,  # [R, N]  residual col factor
) -> jnp.ndarray:
    """y = x @ (W + A @ B), computed restore-free."""
    return x.astype(jnp.float32) @ w.astype(jnp.float32) + (
        x.astype(jnp.float32) @ a.astype(jnp.float32)
    ) @ b.astype(jnp.float32)


def grouped_lowrank_matmul_ref(
    xg: jnp.ndarray,  # [E, C, K] dispatched expert bank
    w: jnp.ndarray,  # [K, N]    shared barycenter segment
    a: jnp.ndarray,  # [E, K, R] per-expert residual row factor
    b: jnp.ndarray,  # [E, R, N] per-expert residual col factor
) -> jnp.ndarray:
    """y[e] = xg[e] @ (W + A[e] @ B[e]), computed restore-free per expert."""
    xf = xg.astype(jnp.float32)
    base = jnp.einsum("eck,kn->ecn", xf, w.astype(jnp.float32))
    t = jnp.einsum("eck,ekr->ecr", xf, a.astype(jnp.float32))
    return base + jnp.einsum("ecr,ern->ecn", t, b.astype(jnp.float32))


def grouped_expert_bank_ref(xg, center, u, v, activation="silu"):
    """Full restore-free expert FFN over the bank (GLU-aware oracle).

    Mirrors moe.py's fused math: h = act(x@Wc1 + corr1) [* (x@Wc3 + corr3)],
    y = h@Wc2 + corr2, with corr_s the per-expert low-rank correction.
    """
    from ..models.layers import activation_fn

    act = activation_fn(activation)
    ut = jnp.swapaxes(u, 1, 2)  # [E, r, f]
    h = act(grouped_lowrank_matmul_ref(
        xg, center["w1"], jnp.swapaxes(v["w1"], 1, 2), ut))
    if "w3" in center:
        h = h * grouped_lowrank_matmul_ref(
            xg, center["w3"], jnp.swapaxes(v["w3"], 1, 2), ut)
    return grouped_lowrank_matmul_ref(h, center["w2"], u, v["w2"])


def block_sparse_matmul_ref(
    x: jnp.ndarray,  # [M, K]
    values: jnp.ndarray,  # [nnzb, bk, bn]
    block_row: jnp.ndarray,  # [nnzb] int32
    block_col: jnp.ndarray,  # [nnzb] int32
    n: int,
) -> jnp.ndarray:
    """y = x @ D where D is block-sparse (BCSR coordinates), via densify."""
    m, k = x.shape
    nnzb, bk, bn = values.shape
    d = np.zeros((k, n), np.float32)
    vals = np.asarray(values, np.float32)
    br = np.asarray(block_row)
    bc = np.asarray(block_col)
    for p in range(nnzb):
        d[br[p] * bk : (br[p] + 1) * bk, bc[p] * bn : (bc[p] + 1) * bn] += vals[p]
    return x.astype(jnp.float32) @ jnp.asarray(d)


def swiglu_expert_ref(x, w1, w3, w2):
    """y = (silu(x@w1) * (x@w3)) @ w2 — oracle for the fused expert kernel."""
    import jax

    xf = x.astype(jnp.float32)
    h = jax.nn.silu(xf @ w1.astype(jnp.float32)) * (xf @ w3.astype(jnp.float32))
    return h @ w2.astype(jnp.float32)
