"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def lowrank_restore_matmul_ref(
    x: jnp.ndarray,  # [M, K]
    w: jnp.ndarray,  # [K, N]  barycenter weight
    a: jnp.ndarray,  # [K, R]  residual row factor
    b: jnp.ndarray,  # [R, N]  residual col factor
) -> jnp.ndarray:
    """y = x @ (W + A @ B), computed restore-free."""
    return x.astype(jnp.float32) @ w.astype(jnp.float32) + (
        x.astype(jnp.float32) @ a.astype(jnp.float32)
    ) @ b.astype(jnp.float32)


def block_sparse_matmul_ref(
    x: jnp.ndarray,  # [M, K]
    values: jnp.ndarray,  # [nnzb, bk, bn]
    block_row: jnp.ndarray,  # [nnzb] int32
    block_col: jnp.ndarray,  # [nnzb] int32
    n: int,
) -> jnp.ndarray:
    """y = x @ D where D is block-sparse (BCSR coordinates), via densify."""
    m, k = x.shape
    nnzb, bk, bn = values.shape
    d = np.zeros((k, n), np.float32)
    vals = np.asarray(values, np.float32)
    br = np.asarray(block_row)
    bc = np.asarray(block_col)
    for p in range(nnzb):
        d[br[p] * bk : (br[p] + 1) * bk, bc[p] * bn : (bc[p] + 1) * bn] += vals[p]
    return x.astype(jnp.float32) @ jnp.asarray(d)


def swiglu_expert_ref(x, w1, w3, w2):
    """y = (silu(x@w1) * (x@w3)) @ w2 — oracle for the fused expert kernel."""
    import jax

    xf = x.astype(jnp.float32)
    h = jax.nn.silu(xf @ w1.astype(jnp.float32)) * (xf @ w3.astype(jnp.float32))
    return h @ w2.astype(jnp.float32)
