"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def lowrank_restore_matmul_ref(
    x: jnp.ndarray,  # [M, K]
    w: jnp.ndarray,  # [K, N]  barycenter weight
    a: jnp.ndarray,  # [K, R]  residual row factor
    b: jnp.ndarray,  # [R, N]  residual col factor
) -> jnp.ndarray:
    """y = x @ (W + A @ B), computed restore-free."""
    return x.astype(jnp.float32) @ w.astype(jnp.float32) + (
        x.astype(jnp.float32) @ a.astype(jnp.float32)
    ) @ b.astype(jnp.float32)


def grouped_lowrank_matmul_ref(
    xg: jnp.ndarray,  # [E, C, K] dispatched expert bank
    w: jnp.ndarray,  # [K, N]    shared barycenter segment
    a: jnp.ndarray,  # [E, K, R] per-expert residual row factor
    b: jnp.ndarray,  # [E, R, N] per-expert residual col factor
) -> jnp.ndarray:
    """y[e] = xg[e] @ (W + A[e] @ B[e]), computed restore-free per expert."""
    xf = xg.astype(jnp.float32)
    base = jnp.einsum("eck,kn->ecn", xf, w.astype(jnp.float32))
    t = jnp.einsum("eck,ekr->ecr", xf, a.astype(jnp.float32))
    return base + jnp.einsum("ecr,ern->ecn", t, b.astype(jnp.float32))


def grouped_expert_bank_ref(xg, center, u, v, activation="silu"):
    """Full restore-free expert FFN over the bank (GLU-aware oracle).

    Mirrors moe.py's fused math: h = act(x@Wc1 + corr1) [* (x@Wc3 + corr3)],
    y = h@Wc2 + corr2, with corr_s the per-expert low-rank correction.
    """
    from ..models.layers import activation_fn

    act = activation_fn(activation)
    ut = jnp.swapaxes(u, 1, 2)  # [E, r, f]
    h = act(grouped_lowrank_matmul_ref(
        xg, center["w1"], jnp.swapaxes(v["w1"], 1, 2), ut))
    if "w3" in center:
        h = h * grouped_lowrank_matmul_ref(
            xg, center["w3"], jnp.swapaxes(v["w3"], 1, 2), ut)
    return grouped_lowrank_matmul_ref(h, center["w2"], u, v["w2"])


def token_lowrank_moe_ref(x, expert_ids, gates, center, u, v,
                          activation="silu"):
    """Capacity-free per-token MoE on an SVD store (GLU-aware oracle).

    Mirrors moe.py's fused math pair-by-pair with NO dispatch buffer:
    for every (token t, slot k) pair with expert e = expert_ids[t, k],
    h = act(x_t@Wc1 + (x_t@V1_e^T)@U_e^T) [* (x_t@Wc3 + ...)], and
    y_t = sum_k g_tk * (h@Wc2 + (h@U_e)@V2_e). Duplicate expert ids within
    a token's top-k are legal — each pair contributes independently.
    """
    from ..models.layers import activation_fn

    act = activation_fn(activation)
    xf = x.astype(jnp.float32)
    gf = gates.astype(jnp.float32)
    uf = u.astype(jnp.float32)[expert_ids]  # [T, k, f, r]
    base1 = xf @ center["w1"].astype(jnp.float32)  # [T, f]
    v1 = v["w1"].astype(jnp.float32)[expert_ids]  # [T, k, r, d]
    t1 = jnp.einsum("td,tkrd->tkr", xf, v1)
    h = act(base1[:, None] + jnp.einsum("tkr,tkfr->tkf", t1, uf))
    if "w3" in center:
        base3 = xf @ center["w3"].astype(jnp.float32)
        v3 = v["w3"].astype(jnp.float32)[expert_ids]
        t3 = jnp.einsum("td,tkrd->tkr", xf, v3)
        h = h * (base3[:, None] + jnp.einsum("tkr,tkfr->tkf", t3, uf))
    hbar = jnp.einsum("tkf,tk->tf", h, gf)
    t2 = jnp.einsum("tkf,tkfr->tkr", h, uf)
    v2 = v["w2"].astype(jnp.float32)[expert_ids]  # [T, k, r, d]
    ylr = jnp.einsum("tkr,tkrd,tk->td", t2, v2, gf)
    return hbar @ center["w2"].astype(jnp.float32) + ylr


def block_sparse_matmul_ref(
    x: jnp.ndarray,  # [M, K]
    values: jnp.ndarray,  # [nnzb, bk, bn]
    block_row: jnp.ndarray,  # [nnzb] int32
    block_col: jnp.ndarray,  # [nnzb] int32
    n: int,
) -> jnp.ndarray:
    """y = x @ D where D is block-sparse (BCSR coordinates), via densify."""
    m, k = x.shape
    nnzb, bk, bn = values.shape
    d = np.zeros((k, n), np.float32)
    vals = np.asarray(values, np.float32)
    br = np.asarray(block_row)
    bc = np.asarray(block_col)
    for p in range(nnzb):
        d[br[p] * bk : (br[p] + 1) * bk, bc[p] * bn : (bc[p] + 1) * bn] += vals[p]
    return x.astype(jnp.float32) @ jnp.asarray(d)


def swiglu_expert_ref(x, w1, w3, w2):
    """y = (silu(x@w1) * (x@w3)) @ w2 — oracle for the fused expert kernel."""
    import jax

    xf = x.astype(jnp.float32)
    h = jax.nn.silu(xf @ w1.astype(jnp.float32)) * (xf @ w3.astype(jnp.float32))
    return h @ w2.astype(jnp.float32)
