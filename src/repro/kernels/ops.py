"""Public jit'd wrappers for the Pallas kernels.

On TPU these lower to Mosaic; on CPU (this container) they run the kernel
body in interpret mode, which is how the test-suite validates them against
the ref.py oracles.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .block_sparse import block_sparse_matmul, prepare_bcsr
from .resmoe_grouped import grouped_lowrank_matmul
from .resmoe_lowrank import lowrank_restore_matmul


def resmoe_svd_apply(
    x: jnp.ndarray,  # [T, K]
    center: jnp.ndarray,  # [K, N]
    u: jnp.ndarray,  # row factor in design layout
    v: jnp.ndarray,  # col factor
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Restore-free expert matmul y = x @ (center + (u@v in weight layout)).

    ``u``: [f, r] design-row factor, ``v``: [r, K] design-col slice for this
    segment; weight-layout correction for a [K, f] weight is v^T @ u^T, so
    the kernel's (A, B) are (v^T [K,r], u^T [r, f]=N).
    """
    a = v.T  # [K, r]
    b = u.T  # [r, N]
    return lowrank_restore_matmul(x, center, a, b, interpret=interpret)


def resmoe_grouped_svd_apply(
    xg: jnp.ndarray,  # [E, C, K] dispatched bank
    center: jnp.ndarray,  # [K, N] shared barycenter segment (weight layout)
    u: jnp.ndarray,  # [E, N, r] design-row factors
    v: jnp.ndarray,  # [E, r, K] design-col slices for this segment
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Grouped restore-free bank matmul y[e] = xg[e] @ (center + corr[e]).

    Bank-level counterpart of :func:`resmoe_svd_apply`: the weight-layout
    correction for a [K, N] segment is v[e]^T @ u[e]^T, so the kernel's
    per-expert (A, B) are (swapaxes(v) [E, K, r], swapaxes(u) [E, r, N]).
    """
    a = jnp.swapaxes(v, 1, 2)
    b = jnp.swapaxes(u, 1, 2)
    return grouped_lowrank_matmul(xg, center, a, b, interpret=interpret)


def resmoe_block_apply(
    x: jnp.ndarray,  # [T, K]
    center: jnp.ndarray,  # [K, N]
    bcsr: dict,  # values/col_idx/row_ptr/block_shape from CompressedResidual
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """y = x @ (center + Delta_bcsr): dense base matmul + sparse kernel.

    The BCSR store indexes the residual in *design layout* [f, dd]; callers
    pass the per-segment slice already transposed to weight layout via
    :func:`bcsr_segment_weight_layout`.
    """
    n = center.shape[1]
    base = x.astype(jnp.float32) @ center.astype(jnp.float32)
    vals, brow, bcol, first = bcsr["values"], bcsr["block_row"], bcsr["block_col"], bcsr["is_first"]
    sparse = block_sparse_matmul(
        x, vals, brow, bcol, first, n=n, interpret=interpret
    )
    return base + sparse


def bcsr_from_residual(res, n_cols: int) -> dict:
    """CompressedResidual(method='block') -> kernel-ready arrays."""
    bm, bn = res.block_shape
    row_ptr = np.asarray(res.block_row_ptr)
    nrows = len(row_ptr) - 1
    block_row = np.repeat(np.arange(nrows, dtype=np.int32), np.diff(row_ptr))
    vals, brow, bcol, first = prepare_bcsr(
        res.block_values, block_row, res.block_col_idx, -(-n_cols // bn)
    )
    return {
        "values": jnp.asarray(vals),
        "block_row": jnp.asarray(brow),
        "block_col": jnp.asarray(bcol),
        "is_first": jnp.asarray(first),
    }
