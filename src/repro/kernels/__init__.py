"""Pallas TPU kernels (validated in interpret mode against ref oracles):

  * resmoe_lowrank — fused restore-free ResMoE-SVD matmul (single expert)
  * resmoe_grouped — grouped restore-free matmul over the whole dispatched
                     expert bank (prefill serving hot path, DESIGN.md §4.2)
  * resmoe_token   — ragged capacity-free per-token MoE for decode-sized
                     batches (no dispatch buffer, DESIGN.md §4.4)
  * *_q8 variants  — dequant-fused twins of the grouped/token kernels for
                     the int8 store: int8 factor tiles cast in registers,
                     per-channel scales folded into the f32 accumulators
                     (DESIGN.md §9)
  * block_sparse   — BCSR residual matmul (TPU adaptation of UP)
  * wkv6           — chunked RWKV6 recurrence (state VMEM-resident)
"""
from .block_sparse import block_sparse_matmul, prepare_bcsr
from .ops import (
    bcsr_from_residual,
    resmoe_block_apply,
    resmoe_grouped_svd_apply,
    resmoe_svd_apply,
)
from .resmoe_grouped import grouped_lowrank_matmul, grouped_lowrank_matmul_q8
from .resmoe_lowrank import lowrank_restore_matmul
from .resmoe_token import token_lowrank_moe, token_lowrank_moe_q8
from .wkv6 import wkv6_chunk, wkv6_ref

__all__ = [
    "block_sparse_matmul",
    "prepare_bcsr",
    "bcsr_from_residual",
    "resmoe_block_apply",
    "resmoe_svd_apply",
    "resmoe_grouped_svd_apply",
    "lowrank_restore_matmul",
    "grouped_lowrank_matmul",
    "grouped_lowrank_matmul_q8",
    "token_lowrank_moe",
    "token_lowrank_moe_q8",
    "wkv6_chunk",
    "wkv6_ref",
]
