"""Trip-count-aware cost model over post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically on the CPU backend — a scan of N matmuls reports one matmul's
flops regardless of N).  Our models scan over layers, microbatches and
attention chunks, so compiler numbers undercount by orders of magnitude.

This module re-derives, from the compiled per-device module text:

    flops            — 2 * numel(result) * prod(lhs contracting dims) per
                       dot (recursing into fusions), x while trip counts
    bytes accessed   — operand+result buffer bytes per top-level
                       instruction (post-fusion, so buffers ~= materialized
                       arrays), x while trip counts
    collective bytes — per collective kind, x while trip counts

Trip counts come from the loop condition region: the ROOT is (a fusion
wrapping) ``compare(iv, bound), direction=LT`` with ``bound`` a constant in
the region — which is how counted lax.scan / fori_loop lower.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([^=]*?)\s([a-z][\w\-]*)\((.*)$"
)
_CALL_ATTR = re.compile(r"(?:to_apply|body|calls)=%?([\w\.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "copy", "after-all", "partition-id"}

# TPU-projected byte accounting: ONLY ops that would read/write HBM on a TPU
# lowering contribute bytes. The CPU backend leaves hundreds of standalone
# converts/broadcasts/selects at top level that Mosaic/XLA-TPU would fuse
# into neighboring kernels; counting their buffers overstates HBM traffic by
# an order of magnitude (measured ~20x on llama3 train).
_BYTES_OPS = {
    "fusion", "dot", "convolution", "scatter", "gather", "dynamic-slice",
    "dynamic-update-slice", "reduce", "reduce-window", "sort", "concatenate",
    "transpose", "reshape", "pad", "custom-call", "select-and-scatter",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "rng", "rng-bit-generator", "cholesky",
    "triangular-solve", "fft",
}


def _shapes_in(txt: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _bytes_of(shapes) -> int:
    tot = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        tot += n * _DTYPE_BYTES[dt]
    return tot


def _numel(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


class Instr:
    __slots__ = ("name", "result_txt", "op", "rest", "is_root")

    def __init__(self, name, result_txt, op, rest, is_root):
        self.name = name
        self.result_txt = result_txt
        self.op = op
        self.rest = rest
        self.is_root = is_root


def _split_call_operands(rest: str) -> Tuple[str, str]:
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_hlo(text: str):
    """Returns (computations: name -> [Instr], entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    comment = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment.sub("", raw).rstrip()
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and ("->" in stripped):
                is_entry = stripped.startswith("ENTRY")
                body = stripped[5:].strip() if is_entry else stripped
                name = body.split("(", 1)[0].strip().lstrip("%").strip()
                if name:
                    cur = name
                    comps[cur] = []
                    if is_entry:
                        entry = cur
            continue
        if stripped == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(
                Instr(m.group(1), m.group(2), m.group(3), m.group(4),
                      "ROOT" in line.split("=")[0])
            )
    return comps, entry


class HloCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        self._memo: Dict[str, Dict[str, float]] = {}
        # per-computation symbol tables: instr name -> result shapes.
        # TPU projection: the CPU backend upcasts bf16 dot operands through
        # standalone convert ops (no native bf16 MMA); a TPU MXU reads bf16
        # directly. We therefore resolve operands THROUGH converts (and
        # convert-only fusions) to the source dtype when counting bytes.
        self._shapes: Dict[str, Dict[str, list]] = {}
        self._producer: Dict[str, Dict[str, "Instr"]] = {}
        for cname, instrs in self.comps.items():
            tab = {}
            prod = {}
            for ins in instrs:
                tab[ins.name] = _shapes_in(ins.result_txt)
                prod[ins.name] = ins
            self._shapes[cname] = tab
            self._producer[cname] = prod

    def _resolve_convert(self, comp: str, name: str, depth: int = 0):
        """Follow convert chains to the narrower source buffer's shapes."""
        if depth > 4:
            return None
        ins = self._producer[comp].get(name)
        if ins is None:
            return None
        if ins.op == "convert" or (
            ins.op == "fusion" and ins.name.startswith("convert")
        ):
            operands, _ = _split_call_operands(ins.rest)
            srcs = _OPERAND_RE.findall(operands)
            if len(srcs) == 1:
                deeper = self._resolve_convert(comp, srcs[0], depth + 1)
                if deeper is not None:
                    return deeper
                return self._shapes[comp].get(srcs[0])
        return None

    # -- helpers ---------------------------------------------------------------

    def _operand_shapes(self, comp: str, operands_txt: str) -> list:
        tab = self._shapes[comp]
        out = []
        for name in _OPERAND_RE.findall(operands_txt):
            resolved = self._resolve_convert(comp, name)
            if resolved is not None:
                # cheaper of (converted, source) — TPU reads the source
                if _bytes_of(resolved) < _bytes_of(tab.get(name, [])):
                    out.extend(resolved)
                    continue
            if name in tab:
                out.extend(tab[name])
        return out

    def _trip_count(self, cond_comp: str) -> int:
        instrs = self.comps.get(cond_comp, [])
        consts: Dict[str, int] = {}
        for ins in instrs:
            if ins.op == "constant":
                # rest looks like "4), metadata=..." — value is the operand
                operands, _ = _split_call_operands(ins.rest)
                m = re.match(r"\s*(-?\d+)\s*$", operands)
                if m:
                    consts[ins.name] = int(m.group(1))
        # find the ROOT (compare or fusion wrapping compare)
        root = next((i for i in instrs if i.is_root), None)
        if root is None:
            return 1
        operands, attrs = _split_call_operands(root.rest)
        cand = [consts[n] for n in _OPERAND_RE.findall(operands) if n in consts]
        is_lt = "direction=LT" in root.rest
        if root.op == "fusion":
            m = _CALL_ATTR.search(attrs)
            if m:
                for ins in self.comps.get(m.group(1), []):
                    if ins.op == "compare" and "direction=LT" in ins.rest:
                        is_lt = True
        if is_lt and cand:
            t = max(cand)
            return t if t > 0 else 1
        return 1

    def _dot_flops(self, comp: str, ins: Instr) -> int:
        operands, attrs = _split_call_operands(ins.rest)
        res = _shapes_in(ins.result_txt)
        if not res:
            return 0
        out_numel = _numel(res[0][1])
        m = _CONTRACT_RE.search(attrs)
        ops = self._operand_shapes(comp, operands)
        if not m or not ops:
            return 2 * out_numel
        lhs = ops[0][1]
        k = 1
        if m.group(1):
            for d in m.group(1).split(","):
                di = int(d)
                if di < len(lhs):
                    k *= lhs[di]
        return 2 * out_numel * k

    # -- main recursion ----------------------------------------------------------

    def _zero(self):
        z = {"flops": 0.0, "bytes": 0.0, "coll_total": 0.0}
        for k in COLLECTIVES:
            z[f"coll_{k}"] = 0.0
        return z

    def cost_of(self, comp: str) -> Dict[str, float]:
        if comp in self._memo:
            return self._memo[comp]
        total = self._zero()
        self._memo[comp] = total
        for ins in self.comps.get(comp, []):
            operands, attrs = _split_call_operands(ins.rest)
            base_op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            is_convert_fusion = ins.op == "fusion" and ins.name.startswith(
                ("convert", "wrapped_convert")
            )
            is_inplace_update = base_op in ("dynamic-update-slice", "scatter") or (
                ins.op == "fusion"
                and ("dynamic-update-slice" in ins.name or "scatter" in ins.name)
            )
            if is_inplace_update:
                # XLA aliases the target buffer in-place (inside while loops
                # it always can); charge only the written payload — charging
                # operand+result would bill a full KV-cache copy per layer
                # per decode step (measured 200x inflation on llama decode).
                names = _OPERAND_RE.findall(operands)
                shapes = [self._shapes[comp].get(nm, []) for nm in names]
                sizes = [_bytes_of(s) for s in shapes]
                if sizes:
                    target = max(range(len(sizes)), key=lambda i: sizes[i])
                    upd = sum(b for i, b in enumerate(sizes) if i != target)
                    total["bytes"] += 2 * upd  # read + write of the payload
            elif base_op in _BYTES_OPS and not is_convert_fusion:
                total["bytes"] += _bytes_of(_shapes_in(ins.result_txt))
                total["bytes"] += _bytes_of(self._operand_shapes(comp, operands))
            if ins.op == "while":
                body = _CALL_ATTR.search(attrs)
                cond = _COND_ATTR.search(attrs)
                # prefer the compiler's own annotation when present
                m = re.search(r'known_trip_count[^0-9]*(\d+)', attrs)
                if m:
                    trips = int(m.group(1))
                else:
                    trips = self._trip_count(cond.group(1)) if cond else 1
                if body:
                    sub = self.cost_of(body.group(1))
                    for k in total:
                        total[k] += trips * sub[k]
            elif ins.op in ("fusion", "call", "map", "reduce", "reduce-window",
                            "scatter", "select-and-scatter", "sort",
                            "conditional"):
                m = _CALL_ATTR.search(attrs)
                if m and m.group(1) in self.comps:
                    sub = self.cost_of(m.group(1))
                    total["flops"] += sub["flops"]
                    total["coll_total"] += sub["coll_total"]
                    for k in COLLECTIVES:
                        total[f"coll_{k}"] += sub[f"coll_{k}"]
            elif ins.op == "dot":
                total["flops"] += self._dot_flops(comp, ins)
            elif ins.op.startswith("convolution"):
                total["flops"] += 2 * _numel(_shapes_in(ins.result_txt)[0][1])
            else:
                base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
                if base in COLLECTIVES and not ins.op.endswith("-done"):
                    if base in ("all-gather", "all-reduce", "collective-permute"):
                        moved = _bytes_of(_shapes_in(ins.result_txt))
                    else:  # reduce-scatter / all-to-all
                        moved = _bytes_of(self._operand_shapes(comp, operands))
                    # TPU projection: if the payload is an upcast of a
                    # narrower buffer (CPU inserts bf16->f32 converts before
                    # dots and SPMD reshards the f32), charge source width.
                    raw_names = _OPERAND_RE.findall(operands)
                    raw = []
                    for nm in raw_names:
                        raw.extend(self._shapes[comp].get(nm, []))
                    raw_b = _bytes_of(raw)
                    res_b = _bytes_of(self._operand_shapes(comp, operands))
                    if raw_b > 0 and res_b < raw_b:
                        moved = int(moved * res_b / raw_b)
                    total[f"coll_{base}"] += moved
                    total["coll_total"] += moved
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Dict[str, float]:
        if self.entry is None:
            return self._zero()
        return self.cost_of(self.entry)


def analyze_hlo_text(text: str) -> Dict[str, float]:
    return HloCost(text).entry_cost()


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized across jax versions.

    jax <= 0.4.x returns a one-dict-per-device list; newer jax returns the
    dict directly. Always returns the (first device's) flat dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
