import os

if __name__ == "__main__" or os.environ.get("REPRO_DRYRUN") == "1":
    # MUST run before any jax import — jax locks the device count on first
    # init. Guarded so that merely importing this module (tests, benchmarks)
    # does NOT leak 512 placeholder devices into the process.
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build abstract params/caches (ShapeDtypeStruct — zero
allocation), jit the production step with explicit in/out shardings, then

    lowered  = jax.jit(step, ...).lower(**input_specs)
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # fits-on-chip evidence
    print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

and additionally parse the post-SPMD HLO for per-device collective bytes
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute)
— cost_analysis does not expose them.  Results land in one JSON per cell
under --out (benchmarks/roofline consumes them).

NOTE the import-order contract: XLA_FLAGS is set above BEFORE any jax
import so the CPU platform exposes 512 placeholder devices.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    """Sum bytes of every typed shape literal in ``txt``."""
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Per-device bytes by collective kind, from the post-SPMD module."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "fusion" in ls.split("(")[0]:
            continue
        for kind in _COLLECTIVES:
            # match the op name as the instruction, e.g. "= bf16[...] all-gather("
            if re.search(rf"=\s*[\w\[\],\{{}}\s]*{kind}(-start|-done)?\(", ls):
                # operand bytes: shapes inside the call parens
                call = ls.split(f"{kind}", 1)[1]
                inner = call[call.find("(") + 1 :]
                depth = 1
                buf = []
                for ch in inner:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                    buf.append(ch)
                operand_bytes = _shape_bytes("".join(buf))
                result_bytes = _shape_bytes(ls.split("=", 1)[1].split(kind)[0])
                if kind == "all-gather":
                    moved = result_bytes  # each device receives the gathered
                elif kind in ("all-reduce", "collective-permute"):
                    moved = result_bytes
                else:  # reduce-scatter / all-to-all: operand leaves the device
                    moved = operand_bytes
                if "-done(" in ls:
                    moved = 0  # avoid double counting start/done pairs
                out[kind] += moved
                counts[kind] += 1
                break
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------


def lower_cell(
    arch: str,
    shape_name: str,
    mesh,
    microbatches: Optional[int] = None,
    sharding_overrides: Optional[Dict[str, Optional[str]]] = None,
    apply_mode: Optional[str] = None,
    compressed: bool = False,
):
    """Lower one (arch, shape, mesh) cell; returns (lowered, meta)."""
    import jax
    import jax.numpy as jnp

    from ..configs import SHAPES, get_config
    from ..models import build_model
    from ..optim import cosine_warmup_schedule, make_optimizer
    from ..sharding import make_rules, shardings_from_axes, use_rules
    from .train import _opt_shardings, make_train_step

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    rules = make_rules(mesh, overrides=sharding_overrides)
    if compressed:
        from ..models.model import abstract_compressed_params

        abs_params, axes = abstract_compressed_params(cfg)
    else:
        abs_params, axes = model.abstract_params()
    param_sh = shardings_from_axes(axes, rules, abs_params)

    from jax.sharding import NamedSharding, PartitionSpec as P

    def batch_sh(tree):
        def one(v):
            axes = ("batch",) + (None,) * (len(v.shape) - 1)
            return rules.sharding_for(axes, tuple(v.shape))
        return jax.tree_util.tree_map(one, tree)

    specs = model.input_specs(shape)

    if shape.kind == "train":
        mb = microbatches if microbatches is not None else _default_microbatches(cfg, shape)
        opt = make_optimizer(cfg.optimizer, cosine_warmup_schedule(3e-4, 100, 10000))
        abs_opt = jax.eval_shape(opt.init, abs_params)
        opt_sh = _opt_shardings(abs_opt, abs_params, param_sh, mesh)
        step = make_train_step(model, opt, microbatches=mb)

        def fn(params, opt_state, batch):
            with use_rules(rules):
                return step(params, opt_state, batch)

        jitted = jax.jit(
            fn,
            in_shardings=(param_sh, opt_sh, batch_sh(specs)),
            out_shardings=(param_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(abs_params, abs_opt, specs)
        meta = dict(kind="train", microbatches=mb)
    elif shape.kind == "prefill":
        def fn(params, batch):
            with use_rules(rules):
                from ..models import transformer as _tfm

                logits, _, _ = _tfm.forward(
                    params, batch, cfg, apply_mode=apply_mode, last_only=True
                )
                return logits[:, -1, ...]

        jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh(specs)),
                         out_shardings=None)
        with mesh:
            lowered = jitted.lower(abs_params, specs)
        meta = dict(kind="prefill")
    else:  # decode
        cache_abs, cache_axes = model.abstract_cache(shape.global_batch, shape.seq_len)
        cache_sh = shardings_from_axes(cache_axes, rules, cache_abs)
        pos = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)

        def fn(params, batch, cache, positions):
            with use_rules(rules):
                logits, new_cache = model.decode_step(
                    params, batch, cache, positions, apply_mode=apply_mode
                )
                return logits, new_cache

        jitted = jax.jit(
            fn,
            in_shardings=(param_sh, batch_sh(specs), cache_sh,
                          rules.sharding_for(("batch", None),
                                             (shape.global_batch, 1))),
            out_shardings=(None, cache_sh),
            donate_argnums=(2,),
        )
        with mesh:
            lowered = jitted.lower(abs_params, specs, cache_abs, pos)
        meta = dict(kind="decode")
    return lowered, meta


def _default_microbatches(cfg, shape) -> int:
    """Activation-memory-driven default: keep the live microbatch modest."""
    tokens = shape.seq_len * shape.global_batch
    # target ~64k tokens per microbatch for d_model>=8k, 128k otherwise
    target = 65536 if cfg.d_model >= 8192 else 131072
    mb = max(1, tokens // target)
    while shape.global_batch % mb:
        mb -= 1
    return mb


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             **kw) -> Dict[str, Any]:
    import jax

    from .mesh import make_production_mesh

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    record: Dict[str, Any] = dict(
        arch=arch, shape=shape_name, mesh=mesh_name, status="ok",
    )
    try:
        lowered, meta = lower_cell(arch, shape_name, mesh, **kw)
        record.update(meta)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        try:
            mem = compiled.memory_analysis()
            record["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            }
        except Exception as e:  # pragma: no cover — backend-dependent
            record["memory_analysis"] = {"error": repr(e)}
        try:
            from .hlo_cost import xla_cost_analysis

            ca = xla_cost_analysis(compiled)
            record["cost_analysis"] = {
                k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "bytes accessed", "optimal_seconds")
                    or k.startswith("bytes accessed")
                    or k.startswith("utilization")
                )
            }
        except Exception as e:
            record["cost_analysis"] = {"error": repr(e)}
        try:
            hlo = compiled.as_text()
            record["collectives"] = collective_bytes_from_hlo(hlo)
            record["hlo_ops"] = _op_histogram(hlo)
            # trip-count-aware re-derivation (cost_analysis counts loop
            # bodies once — see hlo_cost.py)
            from .hlo_cost import analyze_hlo_text

            record["hlo_cost"] = analyze_hlo_text(hlo)
        except Exception as e:
            record["collectives"] = {"error": repr(e)}
        record["lower_s"] = round(t1 - t0, 2)
        record["compile_s"] = round(t2 - t1, 2)
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"(lower {record['lower_s']}s, compile {record['compile_s']}s)")
        ma = record.get("memory_analysis", {})
        print("  memory_analysis:", ma)
        ca = record.get("cost_analysis", {})
        print("  cost_analysis: flops=%.3e bytes=%.3e" % (
            ca.get("flops", 0.0), ca.get("bytes accessed", 0.0)))
        print("  collectives:", record.get("collectives", {}).get("bytes"))
    except Exception as e:
        record["status"] = "fail"
        record["error"] = repr(e)
        record["traceback"] = traceback.format_exc()
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {e!r}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "_".join(str(v) for v in kw.values() if v is not None)
        fname = f"{arch}__{shape_name}__{mesh_name}" + (f"__{suffix}" if suffix else "")
        with open(os.path.join(out_dir, fname + ".json"), "w") as fh:
            json.dump(record, fh, indent=1)
    return record


def _op_histogram(hlo: str) -> Dict[str, int]:
    hist: Dict[str, int] = {}
    for m in re.finditer(r"=\s*[\w\[\],\{}\s]*?(\b[a-z][\w-]*)\(", hlo):
        op = m.group(1)
        hist[op] = hist.get(op, 0) + 1
    return {k: v for k, v in sorted(hist.items(), key=lambda kv: -kv[1])[:40]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--apply-mode", default=None)
    args = ap.parse_args()

    from ..configs import ASSIGNED, applicable_shapes, get_config

    cells = []
    if args.all:
        for name, cfg in ASSIGNED.items():
            for sh in applicable_shapes(cfg):
                cells.append((name, sh.name))
    else:
        shapes = [args.shape] if args.shape else [
            s.name for s in applicable_shapes(get_config(args.arch))
        ]
        for sh in shapes:
            cells.append((args.arch, sh))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            results.append(run_cell(arch, shape, mp, args.out,
                                    microbatches=args.microbatches,
                                    apply_mode=args.apply_mode))
    ok = sum(r["status"] == "ok" for r in results)
    print(f"[dryrun] {ok}/{len(results)} cells passed")
    if ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
