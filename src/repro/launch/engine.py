"""Overlapped serving engine: decode never blocks on admission or output.

``ContinuousServer`` (launch/serve.py) runs admission, prefill, decode and
detokenize in one synchronous Python loop, so every admission prefill and
every per-step logits readback stalls the live decode slots. This module
wraps the SAME scheduling/state machinery (PagePool, ServingState, spec
rounds) in the MaxText/JetStream production shape — three threads around
two bounded queues (DESIGN.md §13):

  * an **admission thread** pulls pending requests off a thread-safe
    deque, packs up to ``admit_batch`` of them into ONE batched prefill
    against a private *mini* paged cache, materializes each row's first
    token on the host, and pushes the finished group onto a bounded ready
    queue — compiles and prefill FLOPs happen here, never on the decode
    thread;
  * the **decode thread** (the ``serve()`` caller) inserts ready rows by
    copying whole KV pages / recurrent state rows from the mini cache onto
    freshly allocated pool pages, then steps all slots with a
    buffer-donated decode whose next tokens stay ON DEVICE — the decode
    thread never waits for a device->host transfer;
  * a **detokenize thread** performs the blocking ``np.asarray`` readback,
    appends tokens to ``Request.output``, and reports EOS back through a
    done queue.

Batched prefill-insert and the PR-5 MoE capacity caveat: a padded/batched
MoE prefill normally computes expert capacity from the GLOBAL token count,
letting batchmates compete for capacity slots — which changes which real
tokens drop versus the B=1 oracle. The engine solves it per ISSUE 8:
same-length groups run the dispatched paths under ``capacity_per_row=True``
(models/moe.py::make_dispatch_per_row — each row gets its own B=1
capacity, bitwise-equal dispatch), and prompt lengths the oracle serves
through the ragged per-token path run ``apply_mode="fused_token"``, which
is capacity-free by construction at any batch size. Recurrent rows are
never padded (dummy tail tokens would advance the recurrence).

Token identity (proof sketch in DESIGN.md §13): every per-row prefill
path above equals the oracle's B=1 prefill for that row; page placement
is invisible through block-table indirection; EOS handled one step late
only stops *scheduling* later (the detokenizer stops appending at EOS, and
preemption-restore recomputes from prompt+generated, so extra "zombie"
decode steps never reach an output). Greedy-only — the engine refuses
``greedy=False`` (a shared rng stream cannot be consumed from two threads
in a defined order) and refuses ``rules`` (the EP gate keys on global
token count, which a batched prefill would flip against the oracle).
"""
from __future__ import annotations

import collections
import dataclasses
import queue as queue_lib
import threading
import time
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model, iter_compressed_stores
from ..sharding import split_logical
from .serve import ContinuousServer, Request, _Pending

PyTree = Any


class _AdmitQueue:
    """Thread-safe admission deque: decode thread feeds arrivals (and
    re-queues preemption victims at the FRONT, preserving the oracle's
    resume-first policy); the admission thread takes same-length groups.
    """

    def __init__(self):
        self._d: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._closed = False

    def __len__(self) -> int:
        with self._cv:
            return len(self._d)

    def put(self, ent: _Pending):
        with self._cv:
            self._d.append(ent)
            self._cv.notify()

    def put_front(self, ent: _Pending):
        with self._cv:
            self._d.appendleft(ent)
            self._cv.notify()

    def close(self):
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def drain(self) -> List[_Pending]:
        """Remove and return every queued entry (stall teardown)."""
        with self._cv:
            out = list(self._d)
            self._d.clear()
            return out

    def take_group(self, max_rows: int, exact: bool) -> List[_Pending]:
        """Block for the head entry, then gather up to ``max_rows`` rows.

        ``exact`` (MoE dispatched / recurrent stacks) admits only rows of
        the head's length — later same-length entries may be pulled past
        a mismatched one (the head itself always goes, so nothing
        starves). Returns [] once closed and drained.
        """
        with self._cv:
            while not self._d and not self._closed:
                self._cv.wait()
            if not self._d:
                return []
            head = self._d.popleft()
            group = [head]
            if exact:
                want = len(head.toks)
                kept = []
                while self._d and len(group) < max_rows:
                    ent = self._d.popleft()
                    if len(ent.toks) == want:
                        group.append(ent)
                    else:
                        kept.append(ent)
                for ent in reversed(kept):
                    self._d.appendleft(ent)
            else:
                while self._d and len(group) < max_rows:
                    group.append(self._d.popleft())
            return group


@dataclasses.dataclass
class _Ready:
    """One prefilled group awaiting insertion on the decode thread."""
    entries: List[_Pending]
    lens: List[int]
    first: List[int]  # host first token per row (argmax at the true end)
    mini: PyTree      # private mini paged cache holding the rows' KV/state
    pages_per_row: int
    next_row: int = 0


class OverlappedServer(ContinuousServer):
    """JetStream-style overlapped engine over ContinuousServer's state.

    Same constructor as :class:`ContinuousServer` plus:

    ``admit_batch``
        rows per batched prefill group (and the fixed batch dimension of
        the group prefill compile — smaller groups are padded with dummy
        rows whose mini block tables stay unmapped, so their writes drop).
    ``queue_depth``
        bound on the ready queue (prefilled groups waiting for slots) and
        the detokenize queue (decode steps awaiting readback) — bounded so
        a stalled consumer applies backpressure instead of hoarding
        device memory.
    ``stall_timeout_s``
        progress watchdog (default 300s): with requests outstanding but no
        token/insertion/arrival movement for this long, ``serve()`` shuts
        the background threads down, drains every queue, and raises a
        descriptive error instead of hanging the caller.

    Restrictions: ``greedy=True`` only, ``rules=None`` only (see module
    docstring). With ``spec_k >= 2`` decode runs the inherited synchronous
    spec rounds (drafting forces host round-trips anyway) — admission
    still overlaps.
    """

    def __init__(self, *args, admit_batch: int = 4, queue_depth: int = 8,
                 stall_timeout_s: float = 300.0, **kwargs):
        super().__init__(*args, **kwargs)
        if not self.greedy:
            raise ValueError(
                "OverlappedServer is greedy-only: sampling consumes a "
                "shared rng stream whose split order the detokenize "
                "thread cannot reproduce — use ContinuousServer")
        if self.rules is not None:
            raise ValueError(
                "OverlappedServer refuses sharding rules: the EP gate "
                "keys on the global token count, so a batched prefill "
                "could route differently from the B=1 oracle — use "
                "ContinuousServer for mesh serving")
        self.admit_batch = max(1, int(admit_batch))
        self.queue_depth = max(1, int(queue_depth))
        # progress watchdog: serve() raises after this long with requests
        # outstanding but no token, insertion, or arrival movement — a
        # wedged admission pipeline otherwise hangs the caller forever
        self.stall_timeout_s = float(stall_timeout_s)
        self._stalled = False
        # test seam: called by the admission thread with each group just
        # before its batched prefill (tests inject a blocking hook here to
        # exercise the stall watchdog + bounded teardown deterministically)
        self._admit_hook = None
        cfg = self.model.cfg
        # exact-length grouping for stacks whose prefill is not
        # padding-neutral — the same predicate that defaults
        # prefill_bucket to 1 in ContinuousServer
        self._exact = bool(cfg.is_moe or cfg.recurrent_type != "none")
        # one representative compressed store: the token-path gate keys
        # only on key presence (center/u/v), which is uniform across a
        # model's compressed layers
        self._store0 = (next((f for _, _, f in
                              iter_compressed_stores(self.params)), None)
                        if cfg.is_moe else None)
        model = self.model
        apply_mode = self.apply_mode
        # group prefill twins: per-row capacity on the dispatched paths,
        # or the capacity-free per-token path when the oracle's B=1
        # prefill would take it (_group_uses_token_path)
        self._prefill_row = jax.jit(
            lambda p, b, c, pos: model.prefill(
                p, b, c, positions=pos, last_only=False,
                apply_mode=apply_mode, capacity_per_row=True))
        self._prefill_tok = jax.jit(
            lambda p, b, c, pos: model.prefill(
                p, b, c, positions=pos, last_only=False,
                apply_mode="fused_token"))
        # donated decode: the previous step's cache buffers are reused in
        # place, and next tokens stay on device (argmax in a tiny follow-on
        # jit over the SAME materialized logits the oracle reads — bitwise
        # the same tokens, no device->host sync on this thread)
        self._ostep = jax.jit(
            lambda p, toks, c, pos: model.decode_step(
                p, {"tokens": toks[:, None]}, c, pos,
                apply_mode=apply_mode),
            donate_argnums=(2,))
        self._argmax_last = jax.jit(
            lambda lg: jnp.argmax(lg[:, -1, :], axis=-1).astype(jnp.int32))
        self._cur_toks = jnp.zeros((self.num_slots,), jnp.int32)
        self._slot_gen = np.zeros(self.num_slots, np.int64)
        self._slot_emitted = np.zeros(self.num_slots, np.int64)
        self.stats.update({
            "admit_groups": 0, "admit_grouped_rows": 0,
            "peak_admit_depth": 0, "peak_ready_depth": 0,
            "peak_detok_depth": 0, "stalls": 0,
        })
        self._started = False
        self._thread_exc: Optional[BaseException] = None
        self._detok_tokens = 0
        self._remaining = 0
        self._admitq: Optional[_AdmitQueue] = None
        self._ready_q: Optional[queue_lib.Queue] = None
        self._detok_q: Optional[queue_lib.Queue] = None
        self._done_q: collections.deque = collections.deque()

    # -- path selection ---------------------------------------------------------

    def _group_uses_token_path(self, length: int) -> bool:
        """Mirror the oracle's per-length MoE path choice: True iff a B=1
        prefill of ``length`` tokens would take the ragged per-token path
        (capacity-free, exact at any batch size) — then the group forces
        ``fused_token``; otherwise the group runs per-row capacity."""
        if self._store0 is None:
            return False
        from ..models.moe import token_path_applicable

        mode = self.apply_mode or self.model.cfg.resmoe.apply_mode
        return token_path_applicable(self._store0, self.model.cfg.moe,
                                     mode, length, rules=None)

    # -- admission thread: batched prefill into a mini paged cache --------------

    def _mini_cache(self, length: int, lens: List[int]) -> Tuple[PyTree, int]:
        """A private ``admit_batch``-row paged cache for one group.

        Row ``g``'s logical page ``j`` maps to mini-physical page
        ``g * P + j`` only for the ceil(lens[g]/page_size) pages the B=1
        oracle would allocate — writes past them (padded tails, dummy
        rows) drop exactly as they do against the big pool. Same config
        => same tree structure as ``self.cache``, so ``self.cache_axes``
        names every leaf's logical axes for both.
        """
        g_rows = self.admit_batch
        pages = -(-length // self.page_size)
        mini, _ = split_logical(self.model.init_paged_cache(
            g_rows, length, self.page_size, g_rows * pages))
        tbl = np.full((g_rows, pages), -1, np.int32)
        for g, s in enumerate(lens):
            n = -(-s // self.page_size)
            tbl[g, :n] = g * pages + np.arange(n, dtype=np.int32)
        tbl_j = jnp.asarray(tbl)

        def upd(leaf, axes):
            if "page_table" not in axes:
                return leaf
            return jnp.broadcast_to(tbl_j, leaf.shape)

        mini = jax.tree_util.tree_map(
            upd, mini, self.cache_axes,
            is_leaf=lambda x: hasattr(x, "shape"))
        return mini, pages

    def _prefill_group(self, group: List[_Pending]) -> _Ready:
        """One batched prefill for up to ``admit_batch`` pending rows."""
        g_rows = self.admit_batch
        lens = [len(ent.toks) for ent in group]
        if self._exact:
            length = lens[0]  # take_group guarantees same-length rows
        else:
            length = min(
                -(-max(lens) // self.prefill_bucket) * self.prefill_bucket,
                self.max_seq)
        toks = np.zeros((g_rows, length), np.int32)
        for g, ent in enumerate(group):
            toks[g, :lens[g]] = ent.toks
        mini, pages = self._mini_cache(length, lens)
        pos = jnp.broadcast_to(jnp.arange(length, dtype=jnp.int32),
                               (g_rows, length))
        fn = (self._prefill_tok if self._group_uses_token_path(length)
              else self._prefill_row)
        logits, mini = fn(self.params, {"tokens": jnp.asarray(toks)},
                          mini, pos)
        last = np.asarray(lens + [1] * (g_rows - len(group)), np.int32) - 1
        first = np.asarray(self._argmax_last(
            logits[jnp.arange(g_rows), jnp.asarray(last)][:, None, :]))
        self.stats["admit_groups"] += 1
        self.stats["admit_grouped_rows"] += len(group)
        return _Ready(entries=group, lens=lens,
                      first=[int(first[g]) for g in range(len(group))],
                      mini=mini, pages_per_row=pages)

    def _admission_main(self):
        try:
            while True:
                group = self._admitq.take_group(self.admit_batch,
                                                self._exact)
                if not group:
                    return  # closed and drained
                if self._admit_hook is not None:
                    self._admit_hook(group)
                self._ready_q.put(self._prefill_group(group))
        except BaseException as exc:  # noqa: BLE001 — surfaced on serve()
            self._thread_exc = exc

    # -- detokenize thread: the only place that blocks on device->host ----------

    def _detok_main(self):
        dead: dict = {}  # slot -> generation whose EOS already landed
        while True:
            item = self._detok_q.get()
            if item is None:
                self._detok_q.task_done()
                return
            if self._thread_exc is not None:
                self._detok_q.task_done()  # keep join() from deadlocking
                continue
            try:
                dev_toks, jobs = item
                toks = np.asarray(dev_toks)  # blocks until the step lands
                now = time.perf_counter()
                for slot, gen, req in jobs:
                    if dead.get(slot) == gen:
                        continue  # zombie step after EOS: never emitted
                    tok = int(toks[slot])
                    req.output.append(tok)
                    if self.record_token_times:
                        if req.token_times is None:
                            req.token_times = []
                        req.token_times.append(now)
                    self._detok_tokens += 1
                    if req.eos_id is not None and tok == req.eos_id:
                        dead[slot] = gen
                        self._done_q.append((slot, gen))
            except BaseException as exc:  # noqa: BLE001
                self._thread_exc = exc
            finally:
                self._detok_q.task_done()

    # -- decode thread ----------------------------------------------------------

    def _raise_thread_exc(self):
        if self._thread_exc is not None:
            exc, self._thread_exc = self._thread_exc, None
            raise RuntimeError(
                "OverlappedServer background thread failed") from exc

    def _release(self, slot: int):
        # generation bump: detok events and jobs for the old occupant are
        # recognizably stale wherever they are in flight
        self._slot_gen[slot] += 1
        super()._release(slot)

    def _finish_slot(self, slot: int):
        self._release(slot)
        self._remaining -= 1

    def _apply_done_events(self):
        while True:
            try:
                slot, gen = self._done_q.popleft()
            except IndexError:
                return
            if not self.slot_free[slot] and self._slot_gen[slot] == gen:
                # EOS observed by the detokenizer: the request's output
                # already ends at the EOS token; free its state. A count-
                # finished slot got here first -> the gen mismatches and
                # the stale event is dropped (no double finish).
                self._finish_slot(slot)

    def _drain_detok(self):
        """Make Request.output authoritative: wait out the detok queue and
        apply any EOS it discovered. Called before anything that READS
        outputs concurrently with the detokenizer (preemption resume)."""
        if self._detok_q is not None:
            self._detok_q.join()
        self._apply_done_events()

    def _preempt(self, slot: int, queue=None) -> None:
        # the inherited _ensure_pages passes its queue arg; the engine
        # re-queues on the admission deque instead (front — the oracle's
        # resume-first policy), after draining the detokenizer so the
        # resume tokens are complete
        self._drain_detok()
        if self.slot_free[slot]:
            return  # EOS landed during the drain; nothing left to evict
        req = self.slot_req[slot]
        orig = self.slot_orig[slot]
        resume = np.concatenate(
            [orig, np.asarray(req.output, np.int32)]).astype(np.int32)
        self._release(slot)
        self._admitq.put_front(_Pending(req=req, toks=resume, orig=orig,
                                        resumed=True))
        self.stats["preemptions"] += 1

    def _insert_rows(self, ready: _Ready) -> bool:
        """Insert as many of the group's remaining rows as slots/pages
        allow: page/state bookkeeping first, then ONE device copy for all
        rows inserted this call. Returns True if any row was consumed."""
        pairs: List[Tuple[int, int]] = []  # (group row, slot)
        progressed = False
        while ready.next_row < len(ready.entries):
            g = ready.next_row
            ent = ready.entries[g]
            req = ent.req
            s = ready.lens[g]
            tok = ready.first[g]
            out_len = (len(req.output) + 1) if ent.resumed else 1
            done = (out_len >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or s >= self.max_seq)
            if done:
                # finish-at-admit (same rules as the oracle): emit the
                # prefill token and never occupy a slot
                if ent.resumed:
                    req.output.append(tok)
                else:
                    req.output = [tok]
                self._stamp(req)
                self.stats["tokens"] += 1
                self._remaining -= 1
                ready.next_row += 1
                progressed = True
                continue
            free = [i for i in range(self.num_slots) if self.slot_free[i]
                    and all(i != sl for _, sl in pairs)]
            if not free or not self.state.admit_ok(s):
                break  # head-block: wait for decode to free slots/pages
            slot = free[0]
            if self.state.prepare(slot, s):
                self._bt_dirty = True
            if ent.resumed:
                req.output.append(tok)
            else:
                req.output = [tok]
            self._stamp(req)
            self.stats["tokens"] += 1
            self.slot_free[slot] = False
            self.slot_pos[slot] = s
            self.slot_req[slot] = req
            self.slot_last_tok[slot] = tok
            self.slot_orig[slot] = ent.orig
            self.slot_seq[slot] = self._admit_counter
            self._admit_counter += 1
            self._slot_emitted[slot] = len(req.output)
            self._cur_toks = self._cur_toks.at[slot].set(tok)
            pairs.append((g, slot))
            ready.next_row += 1
            progressed = True
        if pairs:
            self._sync_block_tables()
            self._copy_rows(ready, pairs)
        return progressed

    def _copy_rows(self, ready: _Ready, pairs: List[Tuple[int, int]]):
        """Copy whole mini-cache pages onto the slots' pool pages and mini
        state rows onto the slots' state rows — the batched analogue of
        the oracle's prefill-merge, in one tree_map."""
        pages_per_row = ready.pages_per_row
        src_pages: List[int] = []
        dst_pages: List[int] = []
        src_rows: List[int] = []
        dst_slots: List[int] = []
        for g, slot in pairs:
            if self.pool is not None:
                dst = self.pool.mapped_pages(slot, ready.lens[g])
                src_pages.extend(g * pages_per_row + j
                                 for j in range(len(dst)))
                dst_pages.extend(dst)
            src_rows.append(g)
            dst_slots.append(slot)
        sp = jnp.asarray(src_pages, jnp.int32) if src_pages else None
        dp = jnp.asarray(dst_pages, jnp.int32) if dst_pages else None
        sr = jnp.asarray(src_rows, jnp.int32)
        dr = jnp.asarray(dst_slots, jnp.int32)

        def cp(big, small, axes):
            if "page_table" in axes:
                return big  # host-authoritative, synced separately
            if "pages" in axes:
                if sp is None:
                    return big
                ax = axes.index("pages")
                idx = [slice(None)] * big.ndim
                idx[ax] = dp
                return big.at[tuple(idx)].set(jnp.take(small, sp, axis=ax))
            if "batch" in axes:
                # recurrent state rows: wholesale replacement, which also
                # obsoletes the oracle's pre-admit state zeroing
                ax = axes.index("batch")
                idx = [slice(None)] * big.ndim
                idx[ax] = dr
                return big.at[tuple(idx)].set(jnp.take(small, sr, axis=ax))
            return big

        self.cache = jax.tree_util.tree_map(
            cp, self.cache, ready.mini, self.cache_axes,
            is_leaf=lambda x: hasattr(x, "shape"))

    def _emit(self, slot: int, tok: int) -> bool:
        # spec-mode (synchronous) emission path; async decode bypasses it
        done = super()._emit(slot, tok)
        if done:
            self._remaining -= 1
        return done

    def _overlap_step(self):
        """One donated decode step; tokens stay on device, the readback is
        the detokenize thread's problem."""
        pos = jnp.asarray(self.slot_pos, jnp.int32)[:, None]
        logits, self.cache = self._ostep(self.params, self._cur_toks,
                                         self.cache, pos)
        nxt = self._argmax_last(logits)
        self._cur_toks = nxt
        jobs = [(slot, int(self._slot_gen[slot]), self.slot_req[slot])
                for slot in self._active_slots()]
        self.stats["peak_detok_depth"] = max(
            self.stats["peak_detok_depth"], self._detok_q.qsize() + 1)
        self._detok_q.put((nxt, jobs))
        for slot, _, req in jobs:
            self.slot_pos[slot] += 1
            self._slot_emitted[slot] += 1
            # count-based done rules live here (no token value needed);
            # EOS arrives later through the done queue
            if (self._slot_emitted[slot] >= req.max_new_tokens
                    or self.slot_pos[slot] >= self.max_seq):
                self._finish_slot(slot)
        self._close_step()

    # -- lifecycle --------------------------------------------------------------

    def warmup(self, max_len: Optional[int] = None):
        """Precompile the engine's full shape set: one batched group
        prefill per admissible length (every length up to the cap for
        exact-length stacks, bucket multiples otherwise — resume lengths
        are data-dependent, so the cap must cover prompt+budget), plus the
        donated decode step, and with ``spec_k >= 2`` the drafter step and
        every [B, k] verify shape the headroom cap can shrink a round to.
        """
        assert all(self.slot_free), "warmup() must run before traffic"
        assert not self._started, "warmup() must run outside serve()"
        cap = self.max_seq if max_len is None else min(max_len,
                                                       self.max_seq)
        if self._exact:
            shapes = set(range(1, cap + 1))
        else:
            shapes = set(range(self.prefill_bucket, cap + 1,
                               self.prefill_bucket))
            shapes.add(cap)
        for length in sorted(shapes):
            ent = _Pending(req=Request(prompt=np.zeros(1, np.int32)),
                           toks=np.zeros(length, np.int32),
                           orig=np.zeros(length, np.int32))
            self._prefill_group([ent])
        self.stats["admit_groups"] = 0
        self.stats["admit_grouped_rows"] = 0
        toks = jnp.zeros((self.num_slots, 1), jnp.int32)
        pos = jnp.zeros((self.num_slots, 1), jnp.int32)
        if self.spec_k >= 2:
            # synchronous spec decode reuses the inherited [B, 1]/[B, k]
            # executables — warm the same set ContinuousServer.warmup does
            self._decode(self.params, {"tokens": toks}, self.cache, pos)
            self.drafter.step(self.params, {"tokens": toks}, self.cache,
                              pos)
            for k in range(2, self.spec_k + 1):
                vt = jnp.zeros((self.num_slots, k), jnp.int32)
                vp = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32),
                                      (self.num_slots, k))
                self._decode(self.params, {"tokens": vt}, self.cache, vp)
        else:
            # donated: the pristine cache buffers are consumed, so keep
            # the returned ones (every table row is unmapped — the dummy
            # writes all dropped)
            logits, self.cache = self._ostep(self.params, self._cur_toks,
                                             self.cache, pos)
            self._argmax_last(logits)
            self._cur_toks = jnp.zeros((self.num_slots,), jnp.int32)

    def serve(self, requests: Sequence[Request],
              arrival_steps: Optional[Sequence[int]] = None
              ) -> List[Request]:
        """Same contract as ContinuousServer.serve; overlapped execution."""
        validated = [self._validate(r) for r in requests]
        if arrival_steps is None:
            arrival = [0] * len(requests)
        else:
            if len(arrival_steps) != len(requests):
                raise ValueError("arrival_steps must match requests")
            arrival = [int(a) for a in arrival_steps]
        self._remaining = len(requests)
        entries = []
        for i, (req, toks) in enumerate(zip(requests, validated)):
            if req.max_new_tokens <= 0:
                req.output = []
                self._remaining -= 1
                continue
            entries.append((arrival[i], i, _Pending(req=req, toks=toks,
                                                    orig=toks)))
        waiting = collections.deque(sorted(entries, key=lambda e: (e[0],
                                                                   e[1])))
        self._admitq = _AdmitQueue()
        self._ready_q: queue_lib.Queue = queue_lib.Queue(
            maxsize=self.queue_depth)
        self._detok_q: queue_lib.Queue = queue_lib.Queue(
            maxsize=self.queue_depth)
        self._done_q: collections.deque = collections.deque()
        self._detok_tokens = 0
        self._thread_exc = None
        self._stalled = False
        self._started = True
        admit_t = threading.Thread(target=self._admission_main,
                                   name="admit", daemon=True)
        detok_t = threading.Thread(target=self._detok_main, name="detok",
                                   daemon=True)
        admit_t.start()
        detok_t.start()
        pending: collections.deque = collections.deque()
        clock = 0
        last_progress = time.monotonic()
        try:
            while self._remaining > 0:
                self._raise_thread_exc()
                before = self._remaining
                self._apply_done_events()
                while waiting and waiting[0][0] <= clock:
                    self._admitq.put(waiting.popleft()[2])
                    self.stats["peak_admit_depth"] = max(
                        self.stats["peak_admit_depth"], len(self._admitq))
                while True:
                    try:
                        pending.append(self._ready_q.get_nowait())
                    except queue_lib.Empty:
                        break
                self.stats["peak_ready_depth"] = max(
                    self.stats["peak_ready_depth"], len(pending))
                inserted = False
                while pending:
                    # strict FIFO over groups (oracle head-blocking): a
                    # stalled head group is not overtaken by a later one
                    head = pending[0]
                    inserted |= self._insert_rows(head)
                    if head.next_row < len(head.entries):
                        break
                    pending.popleft()
                if not self._active_slots():
                    clock += 1
                    if self._remaining > 0 and not inserted \
                            and before == self._remaining:
                        if waiting:
                            continue  # spin the clock toward arrivals
                        # work is in flight on the admission thread
                        try:
                            pending.append(self._ready_q.get(timeout=0.005))
                        except queue_lib.Empty:
                            pass
                        elapsed = time.monotonic() - last_progress
                        if elapsed > self.stall_timeout_s:
                            self._stalled = True
                            self.stats["stalls"] += 1
                            raise RuntimeError(
                                f"OverlappedServer stalled: no progress "
                                f"for {elapsed:.1f}s (stall_timeout_s="
                                f"{self.stall_timeout_s:g}) with "
                                f"{self._remaining} request(s) "
                                f"outstanding — admission thread "
                                f"{'alive' if admit_t.is_alive() else 'dead'}, "
                                f"{len(self._admitq)} pending admission(s), "
                                f"{len(pending) + self._ready_q.qsize()} "
                                f"prefilled group(s) awaiting insertion, "
                                f"{self._detok_q.qsize()} detokenize "
                                f"step(s) queued; background threads were "
                                f"shut down and queues drained")
                    else:
                        last_progress = time.monotonic()
                    continue
                last_progress = time.monotonic()
                self._ensure_pages(self._admitq)
                if (self._preempt_steps
                        and self.stats["steps"] in self._preempt_steps
                        and self._active_slots()):
                    self._preempt_steps.discard(self.stats["steps"])
                    victim = max(self._active_slots(),
                                 key=lambda s: self.slot_seq[s])
                    self._preempt(victim)
                    if not self._active_slots():
                        clock += 1
                        continue
                if self.spec_k >= 2:
                    self._step_all()
                else:
                    self._overlap_step()
                clock += 1
        finally:
            self._admitq.close()
            # BOUNDED teardown. On the normal path the admission thread is
            # parked in take_group and exits on close() within one loop
            # turn; after a detected stall it may be wedged INSIDE a
            # prefill, and an unbounded join here would trap the caller in
            # this finally forever — the exact hang the watchdog exists to
            # convert into an error. So: keep the bounded ready queue
            # draining (a thread blocked mid-put must reach the close
            # signal), but give up after a grace period and abandon the
            # wedged thread — both threads are daemonic and the next
            # serve() builds fresh queues.
            grace = 1.0 if self._stalled else 60.0
            deadline = time.monotonic() + grace
            while admit_t.is_alive() and time.monotonic() < deadline:
                try:
                    self._ready_q.get_nowait()
                except queue_lib.Empty:
                    pass
                admit_t.join(timeout=0.01)
            # drain leftovers: queued groups pin mini-cache device buffers
            # and undelivered admissions would leak into a later serve()
            while True:
                try:
                    self._ready_q.get_nowait()
                except queue_lib.Empty:
                    break
            self._admitq.drain()
            self._done_q.clear()
            # the sentinel put must not block on a full queue whose
            # consumer is wedged mid-readback; a live detokenizer drains
            # the queue and takes it within a turn or two
            sent = False
            stop = time.monotonic() + grace
            while not sent and time.monotonic() < stop:
                try:
                    self._detok_q.put(None, timeout=0.05)
                    sent = True
                except queue_lib.Full:
                    if not detok_t.is_alive():
                        break
            detok_t.join(timeout=grace)
            if detok_t.is_alive():
                while True:  # abandoned: drop its queued steps too
                    try:
                        self._detok_q.get_nowait()
                    except queue_lib.Empty:
                        break
            self.stats["tokens"] += self._detok_tokens
            self._detok_tokens = 0
            self._started = False
        self._raise_thread_exc()
        return list(requests)
