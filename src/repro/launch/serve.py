"""Batched serving driver: continuous batching over fixed decode slots.

Design (vLLM-style, slot-granular):
  * ``Server`` owns a batched cache with ``num_slots`` rows and a jitted
    decode step over all slots.
  * A new request is prefetched alone (B=1 prefill), then its cache row is
    inserted into the batched cache at a free slot (tree-wise
    dynamic_update along each leaf's batch axis — located via the logical
    axes recorded at cache init).
  * Every loop iteration decodes ALL active slots in one step; finished
    slots (max tokens or EOS) are freed and refilled from the queue.

ResMoE integration: pass compressed params and ``apply_mode`` — "restored"
(paper Algorithm 2: restore-on-the-fly), "fused"/"fused_shared"
(beyond-paper restore-free einsum path), "fused_kernel" (restore-free
path on the grouped Pallas kernel, kernels/resmoe_grouped.py — one
pallas_call per expert-FFN segment over the whole dispatched bank; see
DESIGN.md §4.2), or "fused_token" (ragged capacity-free per-token path,
kernels/resmoe_token.py — DESIGN.md §4.4). Decode steps carry only
``num_slots`` tokens, so the restore-free modes take the per-token path
automatically there (``MoEConfig.token_path_max_tokens``) while prefill
keeps the dispatched kernels — one Server, both hot paths.

Compress-once/serve-many: the CLI's ``--store-dir`` boots from a persisted
compressed store (checkpoint/checkpointer.py::load_compressed_store) when
one exists — the barycenter/SVD pipeline never reruns at boot — and
``--store-dtype int8`` serves the int8-quantized store through the
dequant-fused kernels (DESIGN.md §9).

Multi-device serving: pass ``rules`` (a ShardingRules over an active mesh)
and ``param_axes`` (the logical-axes tree matching ``params`` — from
``model.abstract_params()`` for dense weights or
``models.model.abstract_compressed_params(cfg)`` for the ResMoE-SVD
store). The server device_puts the params to their mesh shardings and
traces prefill/decode under the rules context, so a compressed model
whose token batch clears the EP gate routes through the shard_map
expert-parallel layer (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tfm
from ..models.model import Model
from ..sharding import (
    ShardingRules,
    shardings_from_axes,
    split_logical,
    use_rules,
)

PyTree = Any


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the server
    output: Optional[List[int]] = None


class Server:
    def __init__(
        self,
        model: Model,
        params: PyTree,
        num_slots: int = 4,
        max_seq: int = 512,
        apply_mode: Optional[str] = None,
        greedy: bool = True,
        seed: int = 0,
        rules: Optional[ShardingRules] = None,
        param_axes: Optional[PyTree] = None,
        truncate_prompts: bool = False,
    ):
        self.model = model
        self.rules = rules
        if rules is not None and param_axes is not None:
            params = jax.device_put(
                params, shardings_from_axes(param_axes, rules, params)
            )
        self.params = params
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.apply_mode = apply_mode
        self.truncate_prompts = truncate_prompts
        self.greedy = greedy
        self.rng = jax.random.PRNGKey(seed)

        cache_l = model.init_cache(num_slots, max_seq)
        self.cache, self.cache_axes = split_logical(cache_l)
        cache1_l = model.init_cache(1, max_seq)
        self._cache1_template, _ = split_logical(cache1_l)

        def _under_rules(fn):
            # trace/compile under the rules context so activation hints and
            # the EP gate (moe_ep.ep_applicable) see the mesh
            def wrapped(p, b, c, pos):
                with use_rules(rules):
                    return fn(p, b, c, pos)
            return wrapped if rules is not None else fn

        self._decode = jax.jit(_under_rules(
            lambda p, b, c, pos: model.decode_step(
                p, b, c, pos, apply_mode=apply_mode
            )
        ))
        self._prefill = jax.jit(_under_rules(
            # prefill must run the SAME compressed path as decode — it is
            # also the only phase whose token count can clear the EP gate
            lambda p, b, c, pos: model.prefill(
                p, b, c, positions=pos, apply_mode=apply_mode
            )
        ))
        self.slot_free = [True] * num_slots
        self.slot_pos = np.zeros(num_slots, np.int64)  # next position to write
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slot_last_tok = np.zeros(num_slots, np.int64)

    # -- cache row surgery ------------------------------------------------------

    def _batch_axis(self, axes: Tuple) -> int:
        return axes.index("batch")

    def _insert_row(self, row_cache: PyTree, slot: int):
        def ins(big, small, axes):
            ax = self._batch_axis(axes)
            idx = [slice(None)] * big.ndim
            idx[ax] = slice(slot, slot + 1)
            return big.at[tuple(idx)].set(small)

        self.cache = jax.tree_util.tree_map(
            ins, self.cache, row_cache, self.cache_axes,
            is_leaf=lambda x: hasattr(x, "shape"),
        )

    def _fresh_row(self) -> PyTree:
        return jax.tree_util.tree_map(lambda x: x.copy(), self._cache1_template)

    # -- request lifecycle ------------------------------------------------------

    def _validate_prompt(self, req: Request) -> np.ndarray:
        """Prompt tokens as admitted: the B=1 prefill row holds max_seq
        positions and an admitted request must keep at least one writable
        decode position — an oversized prompt used to be accepted and
        silently overrun (clamped writes corrupt the row). Left-truncates
        (keeps the most recent context) under ``truncate_prompts``."""
        toks = np.asarray(req.prompt, np.int32)
        limit = self.max_seq - 1
        if len(toks) > limit:
            if not self.truncate_prompts:
                raise ValueError(
                    f"prompt length {len(toks)} exceeds the cache row: "
                    f"max_seq={self.max_seq} admits at most {limit} prompt "
                    "tokens (pass truncate_prompts=True to left-truncate "
                    "instead)")
            toks = toks[-limit:]
        return toks

    def _admit(self, req: Request, slot: int):
        if req.max_new_tokens <= 0:
            req.output = []
            return
        toks = self._validate_prompt(req)
        s = len(toks)
        pos = jnp.arange(s, dtype=jnp.int32)[None, :]
        row = self._fresh_row()
        logits, row = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)[None, :]}, row, pos
        )
        nxt = int(jnp.argmax(logits[0, -1]))
        req.output = [nxt]
        # prefill already emitted one token — a max_new_tokens=1 (or
        # immediate-EOS) request must finish here, never taking a decode
        # step (it used to overshoot to 2 tokens).
        if len(req.output) >= req.max_new_tokens or (
            req.eos_id is not None and nxt == req.eos_id
        ):
            return
        self._insert_row(row, slot)
        self.slot_free[slot] = False
        self.slot_pos[slot] = s
        self.slot_req[slot] = req
        self.slot_last_tok[slot] = nxt

    def _step_all(self):
        toks = jnp.asarray(self.slot_last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.slot_pos, jnp.int32)[:, None]
        logits, self.cache = self._decode(self.params, {"tokens": toks},
                                          self.cache, pos)
        if self.greedy:
            nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        else:
            self.rng, k = jax.random.split(self.rng)
            nxt = np.asarray(jax.random.categorical(k, logits[:, -1, :]))
        for slot in range(self.num_slots):
            if self.slot_free[slot]:
                continue
            req = self.slot_req[slot]
            self.slot_pos[slot] += 1
            tok = int(nxt[slot])
            req.output.append(tok)
            # slot_pos is the NEXT position to write (already incremented
            # above), so the cache is exhausted only at == max_seq; the
            # old `>= max_seq - 1` left the last writable position unused
            # and truncated sequences one token early.
            done = len(req.output) >= req.max_new_tokens or (
                req.eos_id is not None and tok == req.eos_id
            ) or self.slot_pos[slot] >= self.max_seq
            if done:
                self.slot_free[slot] = True
                self.slot_req[slot] = None
            else:
                self.slot_last_tok[slot] = tok

    def serve(self, requests: Sequence[Request]) -> List[Request]:
        """Run the continuous-batching loop until all requests finish."""
        # reject oversized prompts up front — raising from a mid-loop
        # _admit would abandon already-admitted requests in their slots
        for req in requests:
            self._validate_prompt(req)
        queue = list(requests)
        while queue or not all(self.slot_free):
            for slot in range(self.num_slots):
                # a request may finish AT admit (max_new_tokens=1 / instant
                # EOS) leaving the slot free — keep draining the queue
                while self.slot_free[slot] and queue:
                    self._admit(queue.pop(0), slot)
            if not all(self.slot_free):
                self._step_all()
        return list(requests)


def main():  # pragma: no cover — exercised by examples/serve_compressed.py
    import argparse
    import dataclasses

    from ..configs import reduced_config
    from ..configs.base import ResMoEConfig
    from ..models import build_model

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument(
        "--apply-mode", default=None, choices=ResMoEConfig.APPLY_MODES,
        help="serve a ResMoE-compressed model under this forward path "
             "(default: uncompressed dense experts)",
    )
    ap.add_argument(
        "--token-path-max-tokens", type=int, default=None, metavar="T",
        help="override MoEConfig.token_path_max_tokens: largest token "
             "batch the restore-free modes hand to the ragged per-token "
             "decode path (kernels/resmoe_token.py); 0 keeps every batch "
             "on the dispatched paths",
    )
    ap.add_argument(
        "--mesh", default=None, metavar="DxM",
        help="serve on a (data, model) mesh, e.g. 2x4 — needs that many "
             "devices (CPU: XLA_FLAGS=--xla_force_host_platform_device_count=8); "
             "compressed stores with a restore-free --apply-mode route "
             "through the shard_map expert-parallel layer (DESIGN.md §6)",
    )
    from ..core.quant import STORE_DTYPES

    ap.add_argument(
        "--store-dir", default=None, metavar="DIR",
        help="compress-once/serve-many: boot from the persisted compressed "
             "store in DIR when one exists (no recompression — the "
             "barycenter/SVD pipeline never runs); otherwise compress now "
             "and persist the store there for the next boot. Requires "
             "--apply-mode.",
    )
    ap.add_argument(
        "--store-dtype", default=None, choices=STORE_DTYPES,
        help="serving-store dtype: 'int8' quantizes center/u/v to int8 "
             "with fp32 per-channel scales (~4x fewer factor HBM bytes; "
             "served by the dequant-fused kernels, DESIGN.md §9). "
             "Default: the config's ResMoEConfig.store_dtype (fp32)",
    )
    ap.add_argument(
        "--truncate-prompts", action="store_true",
        help="left-truncate prompts longer than max_seq-1 instead of "
             "rejecting them at admit",
    )
    args = ap.parse_args()
    cfg = reduced_config(args.arch)
    if args.token_path_max_tokens is not None and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, token_path_max_tokens=args.token_path_max_tokens))
    model = build_model(cfg)
    if args.apply_mode is None and (args.store_dir is not None
                                    or args.store_dtype is not None):
        raise SystemExit("--store-dir/--store-dtype require --apply-mode "
                         "(they describe the compressed store)")
    if args.apply_mode is None:
        params, axes = model.init_split(jax.random.PRNGKey(0))
    else:
        from ..checkpoint import (
            has_compressed_store,
            load_compressed_store,
            save_compressed_store,
        )
        from ..models import compress_model_params, quantize_compressed_params
        from ..models.model import abstract_compressed_params

        store_dtype = args.store_dtype or cfg.resmoe.store_dtype
        cfg = dataclasses.replace(
            cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                            store_dtype=store_dtype))
        model = build_model(cfg)
        if args.store_dir is not None and has_compressed_store(args.store_dir):
            # store boot: the persisted tree already holds every serving
            # weight — no dense init, no recompression
            params, meta = load_compressed_store(args.store_dir)
            for key, want in (("arch", args.arch),
                              ("store_dtype", store_dtype),
                              ("method", cfg.resmoe.method),
                              ("keep_ratio", cfg.resmoe.keep_ratio)):
                if meta.get(key) != want:
                    raise SystemExit(
                        f"store at {args.store_dir} has {key}="
                        f"{meta.get(key)!r}, requested {want!r} — pick a "
                        "different --store-dir or matching flags")
            print(f"booted from persisted store {args.store_dir} "
                  f"(dtype={store_dtype}; no recompression)")
        else:
            params, _ = model.init_split(jax.random.PRNGKey(0))
            params, _ = compress_model_params(params, cfg)
            if store_dtype == "int8":
                params = quantize_compressed_params(params)
            if args.store_dir is not None:
                save_compressed_store(
                    args.store_dir, params,
                    meta={"arch": args.arch, "store_dtype": store_dtype,
                          "method": cfg.resmoe.method,
                          "keep_ratio": cfg.resmoe.keep_ratio})
                print(f"compressed and persisted store -> {args.store_dir}")
        _, axes = abstract_compressed_params(cfg, store_dtype=store_dtype)
    rules = None
    if args.mesh is not None:
        from ..sharding import make_rules
        from .mesh import make_mesh

        try:
            shape = tuple(int(s) for s in args.mesh.lower().split("x"))
        except ValueError:
            shape = ()
        if len(shape) != 2:
            raise SystemExit("--mesh must be DxM, e.g. 2x4")
        rules = make_rules(make_mesh(shape, ("data", "model")))
    server = Server(model, params, num_slots=4, max_seq=128,
                    apply_mode=args.apply_mode, rules=rules,
                    param_axes=axes if rules is not None else None,
                    truncate_prompts=args.truncate_prompts)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=(8,)),
                max_new_tokens=args.max_new)
        for _ in range(args.requests)
    ]
    server.serve(reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: {r.output}")


if __name__ == "__main__":
    main()
