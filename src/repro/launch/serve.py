"""Batched serving drivers: slot-synchronous rows and paged continuous
batching.

Two servers share the model-facing machinery:

``Server`` (slot-granular, the differential-test oracle):
  * owns a batched cache with ``num_slots`` full ``max_seq`` rows and a
    jitted decode step over all slots.
  * A new request is prefetched alone (B=1 prefill), then its cache row is
    inserted into the batched cache at a free slot (tree-wise
    dynamic_update along each leaf's batch axis — located via the logical
    axes recorded at cache init).
  * Every loop iteration decodes ALL active slots in one step; finished
    slots (max tokens or EOS) are freed and refilled from the queue.

``ContinuousServer`` (page-granular, vLLM-style — DESIGN.md §10):
  * KV memory is a shared pool of ``page_size``-token pages
    (launch/paging.py) instead of per-slot rows, so a pool far below
    ``num_slots * max_seq`` carries the same traffic;
  * requests join/leave per step through an admission queue (optionally
    replaying an ``arrival_steps`` trace), prefilling straight into freed
    pages while live slots keep decoding;
  * pool exhaustion preempts the most-recently-admitted slot and restores
    it later by recompute — greedy outputs stay token-identical to
    ``Server`` (tests/test_serve.py differential suite).

ResMoE integration: pass compressed params and ``apply_mode`` — "restored"
(paper Algorithm 2: restore-on-the-fly), "fused"/"fused_shared"
(beyond-paper restore-free einsum path), "fused_kernel" (restore-free
path on the grouped Pallas kernel, kernels/resmoe_grouped.py — one
pallas_call per expert-FFN segment over the whole dispatched bank; see
DESIGN.md §4.2), or "fused_token" (ragged capacity-free per-token path,
kernels/resmoe_token.py — DESIGN.md §4.4). Decode steps carry only
``num_slots`` tokens, so the restore-free modes take the per-token path
automatically there (``MoEConfig.token_path_max_tokens``) while prefill
keeps the dispatched kernels — one Server, both hot paths.

Compress-once/serve-many: the CLI's ``--store-dir`` boots from a persisted
compressed store (checkpoint/checkpointer.py::load_compressed_store) when
one exists — the barycenter/SVD pipeline never reruns at boot — and
``--store-dtype int8`` serves the int8-quantized store through the
dequant-fused kernels (DESIGN.md §9).

Multi-device serving: pass ``rules`` (a ShardingRules over an active mesh)
and ``param_axes`` (the logical-axes tree matching ``params`` — from
``model.abstract_params()`` for dense weights or
``models.model.abstract_compressed_params(cfg)`` for the ResMoE-SVD
store). The server device_puts the params to their mesh shardings and
traces prefill/decode under the rules context, so a compressed model
whose token batch clears the EP gate routes through the shard_map
expert-parallel layer (DESIGN.md §6).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tfm
from ..models.model import Model
from ..sharding import (
    ShardingRules,
    shardings_from_axes,
    split_logical,
    use_rules,
)

PyTree = Any


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the server
    output: Optional[List[int]] = None
    # perf_counter stamp per emitted token, filled only when the server
    # was built with record_token_times=True — consecutive deltas are the
    # inter-token (TPOT) latencies the serve bench summarizes as p50/p99
    token_times: Optional[List[float]] = None


def sample_tokens(rng, logits: jnp.ndarray, greedy: bool):
    """Next-token choice shared by both servers: ``(new_rng, tokens)``.

    ``logits`` is ``[..., V]``; greedy argmax consumes no randomness, the
    categorical path splits the rng once per call. One helper so a future
    sampling change (temperature, top-p) lands in every call site — the
    prefill-emitted token once drifted to unconditional argmax precisely
    because this logic was copied inline.
    """
    if greedy:
        return rng, jnp.argmax(logits, axis=-1)
    rng, k = jax.random.split(rng)
    return rng, jax.random.categorical(k, logits)


def validate_prompt(prompt, max_seq: int, truncate: bool) -> np.ndarray:
    """Prompt tokens as admitted, shared by both servers.

    A cache row/slot holds ``max_seq`` positions and an admitted request
    must keep at least one writable decode position, so at most
    ``max_seq - 1`` prompt tokens are admitted — an oversized prompt used
    to be accepted and silently overrun (clamped writes corrupt the row).
    ``truncate`` LEFT-truncates (keeps the most recent context) instead of
    rejecting. An empty prompt — as given, or after a truncation that
    keeps zero tokens (max_seq == 1) — is rejected: there is nothing to
    prefill and the B=1 prefill would trace a [1, 0] batch.
    """
    toks = np.asarray(prompt, np.int32)
    limit = max_seq - 1
    if len(toks) > limit:
        if not truncate:
            raise ValueError(
                f"prompt length {len(toks)} exceeds the cache row: "
                f"max_seq={max_seq} admits at most {limit} prompt "
                "tokens (pass truncate_prompts=True to left-truncate "
                "instead)")
        toks = toks[-limit:] if limit > 0 else toks[:0]
    if len(toks) == 0:
        raise ValueError(
            "empty prompt: nothing to prefill (a truncation that keeps "
            "zero tokens lands here too — raise max_seq or send at least "
            "one token)")
    return toks


class Server:
    def __init__(
        self,
        model: Model,
        params: PyTree,
        num_slots: int = 4,
        max_seq: int = 512,
        apply_mode: Optional[str] = None,
        greedy: bool = True,
        seed: int = 0,
        rules: Optional[ShardingRules] = None,
        param_axes: Optional[PyTree] = None,
        truncate_prompts: bool = False,
        spec_k: int = 0,
    ):
        self.model = model
        self.rules = rules
        if rules is not None and param_axes is not None:
            params = jax.device_put(
                params, shardings_from_axes(param_axes, rules, params)
            )
        self.params = params
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.apply_mode = apply_mode
        self.truncate_prompts = truncate_prompts
        self.greedy = greedy
        self.rng = jax.random.PRNGKey(seed)
        # spec_k >= 2: barycenter-draft speculative decoding (launch/
        # spec.py, DESIGN.md §12) — each round drafts k-1 tokens through
        # the center-only path and verifies them in one T=k forward.
        # spec_k in {0, 1} is plain decode (a 1-token round IS a decode
        # step). Greedy-only; outputs are token-identical either way.
        self.spec_k = int(spec_k)
        self.spec_stats = {"rounds": 0, "drafted": 0, "accepted": 0}
        self.drafter = None
        if self.spec_k >= 2:
            from .spec import CenterDrafter, validate_spec_model

            validate_spec_model(model, params, greedy)
            self.drafter = CenterDrafter(model, rules=rules)

        cache_l = model.init_cache(num_slots, max_seq)
        self.cache, self.cache_axes = split_logical(cache_l)
        cache1_l = model.init_cache(1, max_seq)
        self._cache1_template, _ = split_logical(cache1_l)

        def _under_rules(fn):
            # trace/compile under the rules context so activation hints and
            # the EP gate (moe_ep.ep_applicable) see the mesh
            def wrapped(p, b, c, pos):
                with use_rules(rules):
                    return fn(p, b, c, pos)
            return wrapped if rules is not None else fn

        self._decode = jax.jit(_under_rules(
            lambda p, b, c, pos: model.decode_step(
                p, b, c, pos, apply_mode=apply_mode
            )
        ))
        self._prefill = jax.jit(_under_rules(
            # prefill must run the SAME compressed path as decode — it is
            # also the only phase whose token count can clear the EP gate
            lambda p, b, c, pos: model.prefill(
                p, b, c, positions=pos, apply_mode=apply_mode
            )
        ))
        self.slot_free = [True] * num_slots
        self.slot_pos = np.zeros(num_slots, np.int64)  # next position to write
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slot_last_tok = np.zeros(num_slots, np.int64)

    # -- cache row surgery ------------------------------------------------------

    def _batch_axis(self, axes: Tuple) -> int:
        return axes.index("batch")

    def _insert_row(self, row_cache: PyTree, slot: int):
        def ins(big, small, axes):
            ax = self._batch_axis(axes)
            idx = [slice(None)] * big.ndim
            idx[ax] = slice(slot, slot + 1)
            return big.at[tuple(idx)].set(small)

        self.cache = jax.tree_util.tree_map(
            ins, self.cache, row_cache, self.cache_axes,
            is_leaf=lambda x: hasattr(x, "shape"),
        )

    def _fresh_row(self) -> PyTree:
        return jax.tree_util.tree_map(lambda x: x.copy(), self._cache1_template)

    # -- request lifecycle ------------------------------------------------------

    def _validate_prompt(self, req: Request) -> np.ndarray:
        return validate_prompt(req.prompt, self.max_seq, self.truncate_prompts)

    def _sample(self, logits_row) -> int:
        """Sample one token, advancing the server's rng stream in the
        helper — every call site routes through here so the key-splitting
        discipline (and any future temperature/top-p change) cannot drift
        per site."""
        self.rng, nxt = sample_tokens(self.rng, logits_row, self.greedy)
        return int(nxt)

    def _admit(self, req: Request, slot: int):
        if req.max_new_tokens <= 0:
            req.output = []
            return
        toks = self._validate_prompt(req)
        s = len(toks)
        pos = jnp.arange(s, dtype=jnp.int32)[None, :]
        row = self._fresh_row()
        logits, row = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)[None, :]}, row, pos
        )
        nxt = self._sample(logits[0, -1])
        req.output = [nxt]
        # prefill already emitted one token — a max_new_tokens=1 (or
        # immediate-EOS) request must finish here, never taking a decode
        # step (it used to overshoot to 2 tokens).
        if len(req.output) >= req.max_new_tokens or (
            req.eos_id is not None and nxt == req.eos_id
        ):
            return
        self._insert_row(row, slot)
        self.slot_free[slot] = False
        self.slot_pos[slot] = s
        self.slot_req[slot] = req
        self.slot_last_tok[slot] = nxt

    def _emit(self, slot: int, tok: int) -> bool:
        """Record one generated token for ``slot``: advance the write
        frontier, append to the request, apply the done rules (max_new /
        EOS / cache exhausted). Returns True when the request finished
        (slot freed). slot_pos is the NEXT position to write (already
        incremented here), so the cache is exhausted only at == max_seq;
        the old `>= max_seq - 1` left the last writable position unused
        and truncated sequences one token early."""
        req = self.slot_req[slot]
        self.slot_pos[slot] += 1
        req.output.append(tok)
        done = len(req.output) >= req.max_new_tokens or (
            req.eos_id is not None and tok == req.eos_id
        ) or self.slot_pos[slot] >= self.max_seq
        if done:
            self.slot_free[slot] = True
            self.slot_req[slot] = None
        else:
            self.slot_last_tok[slot] = tok
        return done

    def _step_all(self):
        if self.spec_k >= 2:
            self._spec_step_all()
        else:
            self._plain_step_all()

    def _plain_step_all(self):
        toks = jnp.asarray(self.slot_last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.slot_pos, jnp.int32)[:, None]
        logits, self.cache = self._decode(self.params, {"tokens": toks},
                                          self.cache, pos)
        logits = np.asarray(logits[:, -1, :])
        for slot in range(self.num_slots):
            if self.slot_free[slot]:
                continue
            self._emit(slot, self._sample(logits[slot]))

    def _spec_step_all(self):
        """One speculative round: draft k-1 center-only steps, verify all
        k tokens in one full-path forward, emit the oracle prefix
        (DESIGN.md §12). The round size shrinks to the tightest cache
        headroom across live slots — a position past max_seq would wrap
        the ring cache into live entries — and a k<2 round degenerates to
        a plain decode step."""
        from .spec import accept_lengths

        active = [s for s in range(self.num_slots) if not self.slot_free[s]]
        k = min([self.spec_k]
                + [self.max_seq - int(self.slot_pos[s]) for s in active])
        if k < 2:
            self._plain_step_all()
            return
        drafts, self.cache = self.drafter.draft(
            self.params, self.cache, self.slot_last_tok, self.slot_pos,
            k - 1)
        ver_toks = np.concatenate(
            [np.asarray(self.slot_last_tok)[:, None], drafts], axis=1)
        ver_pos = np.asarray(self.slot_pos)[:, None] + np.arange(k)[None, :]
        logits, self.cache = self._decode(
            self.params, {"tokens": jnp.asarray(ver_toks, jnp.int32)},
            self.cache, jnp.asarray(ver_pos, jnp.int32))
        oracle = np.asarray(jnp.argmax(logits, axis=-1))
        acc = accept_lengths(drafts, oracle)
        self.spec_stats["rounds"] += 1
        for slot in active:
            self.spec_stats["drafted"] += k - 1
            self.spec_stats["accepted"] += int(acc[slot])
            for i in range(int(acc[slot]) + 1):
                if self._emit(slot, int(oracle[slot, i])):
                    break

    def serve(self, requests: Sequence[Request]) -> List[Request]:
        """Run the continuous-batching loop until all requests finish."""
        # reject oversized prompts up front — raising from a mid-loop
        # _admit would abandon already-admitted requests in their slots
        for req in requests:
            self._validate_prompt(req)
        queue = list(requests)
        while queue or not all(self.slot_free):
            for slot in range(self.num_slots):
                # a request may finish AT admit (max_new_tokens=1 / instant
                # EOS) leaving the slot free — keep draining the queue
                while self.slot_free[slot] and queue:
                    self._admit(queue.pop(0), slot)
            if not all(self.slot_free):
                self._step_all()
        return list(requests)


@dataclasses.dataclass
class _Pending:
    """Queue entry: a request plus the exact tokens its prefill will see.

    ``toks`` is the validated (possibly truncated) prompt for fresh
    entries; for a preempted request it is the original prompt PLUS every
    token generated so far, so re-admission restores the sequence by
    recompute — the prefill's last-position logits are exactly what the
    interrupted decode step would have produced, keeping greedy outputs
    token-identical across preemption (DESIGN.md §10). ``orig`` stays the
    original validated prompt so a second preemption rebuilds from it.
    """
    req: Request
    toks: np.ndarray
    orig: np.ndarray
    resumed: bool = False


class ContinuousServer:
    """Continuous-batching scheduler over a paged KV cache.

    Differences from :class:`Server` (kept as the oracle for the
    differential tests):

      * memory is a shared :class:`~repro.launch.paging.PagePool` of
        ``pool_pages`` pages of ``page_size`` tokens instead of a full
        ``max_seq`` cache row per slot — a pool sized well below
        ``num_slots * max_seq`` serves the same traffic because live
        requests rarely all reach ``max_seq`` at once;
      * requests join and leave per step: an admission queue feeds freed
        slots/pages between decode steps (optionally gated by per-request
        ``arrival_steps`` to replay an arrival trace), while live slots
        keep decoding;
      * pool exhaustion preempts the most-recently-admitted slot (vLLM's
        policy): its pages are freed for the needy older request and it is
        re-queued at the FRONT of the admission queue with
        prompt+generated-so-far, restored later by recompute.

    Serving state is composed per mixer kind through the StatePage
    interface (launch/paging.py, DESIGN.md §11): attention layers draw
    token pages from the shared pool, recurrent layers (rglru/rwkv6) hold
    one fixed-size state slot per serving slot, hybrid stacks hold both —
    the scheduler allocates/frees/preempts through ``self.state`` without
    branching on architecture. Preempting a recurrent slot keeps NO state:
    the resume prefill recomputes it from prompt+generated-so-far, which
    is bitwise-identical because the state-carrying prefill scan runs the
    same per-step recurrence as decode. Sliding-window-only stacks also
    reclaim window-expired pages each step (``stats["reclaimed_pages"]``).

    Greedy generations are token-identical to ``Server`` — the paged
    attention view masks exactly the positions the ring cache masks, and
    recompute-restore re-derives the interrupted logits bitwise (pinned by
    the differential suite in tests/test_serve.py across the architecture
    matrix).

    ``preempt_steps`` forces a preemption of the most-recently-admitted
    slot before the given decode-step indices — a deterministic scheduler
    hook for tests/benchmarks to exercise preemption-restore on stacks
    whose state never exhausts naturally (pure-recurrent models hold no
    pages, so pool pressure cannot evict them).
    """

    def __init__(
        self,
        model: Model,
        params: PyTree,
        num_slots: int = 8,
        max_seq: int = 512,
        page_size: int = 16,
        pool_pages: Optional[int] = None,
        apply_mode: Optional[str] = None,
        greedy: bool = True,
        seed: int = 0,
        rules: Optional[ShardingRules] = None,
        param_axes: Optional[PyTree] = None,
        truncate_prompts: bool = False,
        prefill_bucket: Optional[int] = None,
        preempt_steps: Optional[Sequence[int]] = None,
        spec_k: int = 0,
        record_token_times: bool = False,
    ):
        from .paging import ServingState

        self.model = model
        self.rules = rules
        if rules is not None and param_axes is not None:
            params = jax.device_put(
                params, shardings_from_axes(param_axes, rules, params)
            )
        self.params = params
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.state = ServingState(tfm.mixer_layout(model.cfg), num_slots,
                                  max_seq, page_size, pool_pages)
        # None for pure-recurrent stacks (no attention layer, no pages)
        self.pool = self.state.pool
        self._preempt_steps = (None if preempt_steps is None
                               else set(int(s) for s in preempt_steps))
        self.apply_mode = apply_mode
        self.truncate_prompts = truncate_prompts
        self.greedy = greedy
        self.rng = jax.random.PRNGKey(seed)
        # stamp Request.token_times at every emit — off by default (a
        # perf_counter call per token is cheap but not free, and most
        # callers only want outputs)
        self.record_token_times = bool(record_token_times)
        # Admission prefills are right-padded to a multiple of this bucket
        # so the jitted prefill only ever sees a handful of shapes. Without
        # it, every preemption resume (prompt + generated-so-far) arrives
        # at a new length and triggers a fresh XLA compile — resume lengths
        # are data-dependent, so the compile count would be unbounded.
        # Padding is exact for ATTENTION: dummy tail tokens write FUTURE
        # positions, which the causal mask hides from every real query and
        # the decode loop later overwrites in place; logits are read at the
        # true last prompt position. It is NOT neutral for token-count-
        # dependent dispatch: a padded MoE prefill computes expert capacity
        # from the padded count and lets dummy tokens compete for capacity
        # slots (and can flip the token-path/EP gates), changing which REAL
        # tokens drop — so MoE models default to unbucketed prefill
        # (correctness over compile count). Recurrent state is NOT padding-
        # neutral either: dummy tail tokens advance the recurrence (h/wkv/
        # shift taps have no causal mask to hide behind), so recurrent and
        # hybrid stacks also default to unbucketed prefill. Pass
        # prefill_bucket explicitly to opt a deployment back in when its
        # prefills tolerate it (MoE on the capacity-free token path).
        if prefill_bucket is None:
            needs_exact = (model.cfg.is_moe
                           or model.cfg.recurrent_type != "none")
            prefill_bucket = 1 if needs_exact else page_size
        self.prefill_bucket = max(prefill_bucket, 1)

        cache_l = model.init_paged_cache(
            num_slots, max_seq, page_size,
            self.pool.num_pages if self.pool is not None else 1)
        self.cache, self.cache_axes = split_logical(cache_l)

        def _under_rules(fn):
            def wrapped(p, b, c, pos):
                with use_rules(rules):
                    return fn(p, b, c, pos)
            return wrapped if rules is not None else fn

        self._decode = jax.jit(_under_rules(
            lambda p, b, c, pos: model.decode_step(
                p, b, c, pos, apply_mode=apply_mode
            )
        ))
        self._prefill = jax.jit(_under_rules(
            # last_only=False: the bucketed prefill reads logits at the
            # true last prompt position, not the padded tail
            lambda p, b, c, pos: model.prefill(
                p, b, c, positions=pos, last_only=False,
                apply_mode=apply_mode
            )
        ))
        self.slot_free = [True] * num_slots
        self.slot_pos = np.zeros(num_slots, np.int64)  # next position to write
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slot_last_tok = np.zeros(num_slots, np.int64)
        self.slot_orig: List[Optional[np.ndarray]] = [None] * num_slots
        self.slot_seq = np.zeros(num_slots, np.int64)  # admission order
        self._admit_counter = 0
        self._bt_dirty = False
        self.stats = {"steps": 0, "preemptions": 0, "tokens": 0,
                      "peak_pages_in_use": 0, "page_util_sum": 0.0,
                      "reclaimed_pages": 0, "spec_rounds": 0,
                      "spec_drafted": 0, "spec_accepted": 0,
                      "spec_boundary_rejects": 0}
        # barycenter-draft speculative decoding (launch/spec.py,
        # DESIGN.md §12); spec_k in {0, 1} is plain decode. One spec
        # round counts as one stats["steps"] tick so preempt_steps and
        # arrival traces keep their meaning.
        self.spec_k = int(spec_k)
        self.drafter = None
        if self.spec_k >= 2:
            from .spec import CenterDrafter, validate_spec_model

            validate_spec_model(model, params, greedy)
            self.drafter = CenterDrafter(model, rules=rules)

    def warmup(self, max_len: Optional[int] = None):
        """Compile every shape the serving loop can ever need.

        Bucketing makes the prefill shape set FINITE — one per bucket
        multiple up to the cache depth — so a production boot can pay all
        XLA compiles before traffic arrives instead of stalling the loop
        on the first preemption resume (whose padded length may be a
        bucket multiple no fresh prompt has hit yet). ``max_len`` bounds
        the covered sequence length when the deployment knows its longest
        prompt + budget (a preemption resume never exceeds
        prompt + max_new). Runs against the pristine cache: every
        block-table row is unmapped, so the dummy prefill/decode writes
        all drop on the floor.
        """
        assert all(self.slot_free), "warmup() must run before traffic"
        cap = self.max_seq if max_len is None else min(max_len, self.max_seq)
        shapes = set(range(self.prefill_bucket, cap + 1,
                           self.prefill_bucket))
        shapes.add(cap)  # the cap shape when the bucket doesn't divide it
        for s_pad in sorted(shapes):
            toks = jnp.zeros((1, s_pad), jnp.int32)
            pos = jnp.arange(s_pad, dtype=jnp.int32)[None, :]
            self._prefill(self.params, {"tokens": toks},
                          self._slot_view(0), pos)
        toks = jnp.zeros((self.num_slots, 1), jnp.int32)
        pos = jnp.zeros((self.num_slots, 1), jnp.int32)
        self._decode(self.params, {"tokens": toks}, self.cache, pos)
        if self.spec_k >= 2:
            # spec rounds add two shape families: the drafter's [B, 1]
            # center-only step and the [B, k] verify forward for every
            # round size the headroom cap can shrink k to
            self.drafter.step(self.params, {"tokens": toks}, self.cache,
                              pos)
            for k in range(2, self.spec_k + 1):
                vt = jnp.zeros((self.num_slots, k), jnp.int32)
                vp = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32),
                                      (self.num_slots, k))
                self._decode(self.params, {"tokens": vt}, self.cache, vp)

    # -- cache surgery (host-side; mirrors the PagePool into the device tree) ----

    def _tree_map(self, fn, *extra):
        return jax.tree_util.tree_map(
            fn, self.cache, *extra, self.cache_axes,
            is_leaf=lambda x: hasattr(x, "shape"),
        )

    def _sync_block_tables(self):
        """Broadcast the host block tables into every layer's block-table
        leaf — identified by the "page_table" logical axis, NOT by "batch"
        (recurrent state rows carry "batch" too and must never be
        overwritten). Skipped when no allocation changed since last sync,
        and a no-op for pure-recurrent stacks (no pool, no tables)."""
        if not self._bt_dirty or self.pool is None:
            self._bt_dirty = False
            return
        host_tbl = self.pool.block_tables

        def upd(leaf, axes):
            if "page_table" not in axes:
                return leaf
            # fresh device buffer per leaf: with unscanned (per-layer
            # plan) segments leaf.shape == tbl.shape and a shared
            # broadcast_to would alias one buffer into every layer's
            # table, which the engine's donated decode step rejects
            return jnp.broadcast_to(jnp.asarray(host_tbl), leaf.shape)

        self.cache = self._tree_map(upd)
        self._bt_dirty = False

    def _reset_pages(self, pages: List[int]):
        """Stamp freed pages' position rows back to the staleness sentinel
        so a reused page cannot leak its previous owner's positions into
        the causal mask (the k/v payload is dead once pos is stale)."""
        if not pages:
            return
        idx = jnp.asarray(pages)

        def upd(leaf, axes):
            if "pages" not in axes or not jnp.issubdtype(leaf.dtype,
                                                         jnp.integer):
                return leaf
            sl = [slice(None)] * leaf.ndim
            sl[axes.index("pages")] = idx
            return leaf.at[tuple(sl)].set(-tfm.attn.GLOBAL_WINDOW)

        self.cache = self._tree_map(upd)

    def _slot_view(self, slot: int) -> PyTree:
        """The B=1 prefill view: full shared pools, this slot's table row."""
        def sl(leaf, axes):
            if "batch" not in axes:
                return leaf
            idx = [slice(None)] * leaf.ndim
            idx[axes.index("batch")] = slice(slot, slot + 1)
            return leaf[tuple(idx)]

        return self._tree_map(sl)

    def _merge_prefill(self, slot: int, new_view: PyTree):
        """Fold a B=1 prefill result back into the batched cache, per leaf
        kind: shared page pools ("pages" leaves) are taken wholesale (the
        prefill wrote this slot's pages in place), recurrent state rows
        ("batch" leaves) are row-inserted at ``slot`` — discarding them
        would silently lose the state the prefill just computed — and the
        block tables ("page_table") stay host-authoritative."""
        def mg(old, new, axes):
            if "page_table" in axes:
                return old
            if "batch" in axes:
                ax = axes.index("batch")
                idx = [slice(None)] * old.ndim
                idx[ax] = slice(slot, slot + 1)
                return old.at[tuple(idx)].set(new)
            return new

        self.cache = jax.tree_util.tree_map(
            mg, self.cache, new_view, self.cache_axes,
            is_leaf=lambda x: hasattr(x, "shape"),
        )

    def _reset_state(self, slot: int):
        """Zero a slot's recurrent state rows before a fresh prefill.

        Free slots keep riding the batched decode step with padding
        tokens, so their state rows drift — a new admission must start
        from the fresh-init state, which for every recurrent mixer is
        all-zeros (models/recurrent.py init_*_state). No-op on
        pure-attention stacks (their only "batch" leaf is the block
        table, excluded by the "page_table" axis)."""
        if self.state.slots is None:
            return

        def upd(leaf, axes):
            if "batch" not in axes or "page_table" in axes:
                return leaf
            idx = [slice(None)] * leaf.ndim
            idx[axes.index("batch")] = slice(slot, slot + 1)
            return leaf.at[tuple(idx)].set(0)

        self.cache = self._tree_map(upd)

    # -- request lifecycle ------------------------------------------------------

    def _validate(self, req: Request) -> np.ndarray:
        toks = validate_prompt(req.prompt, self.max_seq,
                               self.truncate_prompts)
        if req.max_new_tokens > 0:
            # lifetime demand per state kind: prefill writes len(toks)
            # positions and each further decode step one more, capped by
            # the cache — ServingState accounts pages and state slots
            # separately (hybrid stacks need both)
            self.state.validate_demand(
                len(toks),
                min(len(toks) + req.max_new_tokens - 1, self.max_seq))
        return toks

    def _sample(self, logits_row) -> int:
        self.rng, nxt = sample_tokens(self.rng, logits_row, self.greedy)
        return int(nxt)

    def _stamp(self, req: Request):
        """Append a token timestamp when latency recording is on."""
        if self.record_token_times:
            if req.token_times is None:
                req.token_times = []
            req.token_times.append(time.perf_counter())

    def _admit(self, ent: _Pending, slot: int):
        req = ent.req
        if not ent.resumed and req.max_new_tokens <= 0:
            req.output = []
            return
        toks = ent.toks
        s = len(toks)
        # fresh state for the slot: token pages for the prompt (attention)
        # and a zeroed recurrent state row — the previous occupant's state
        # must not leak into this prefill
        self._reset_state(slot)
        if self.state.prepare(slot, s):
            self._bt_dirty = True
        self._sync_block_tables()
        # bucketed prefill: pad to the next bucket multiple (capped at the
        # cache depth). The dummy tail writes future positions — pages not
        # yet allocated drop the writes, allocated ones get overwritten by
        # the decode loop — and contributes nothing to the causal window.
        s_pad = min(-(-s // self.prefill_bucket) * self.prefill_bucket,
                    self.max_seq)
        padded = np.zeros(s_pad, np.int32)
        padded[:s] = toks
        pos = jnp.arange(s_pad, dtype=jnp.int32)[None, :]
        logits, new_view = self._prefill(
            self.params, {"tokens": jnp.asarray(padded)[None, :]},
            self._slot_view(slot), pos
        )
        self._merge_prefill(slot, new_view)
        self._finish_admit(ent, slot, s, self._sample(logits[0, s - 1]))

    def _finish_admit(self, ent: _Pending, slot: int, s: int, nxt: int):
        """Post-prefill admission bookkeeping, shared with the
        disaggregated decode server (launch/router.py) whose prefill ran
        on a dedicated worker instead of through ``self._prefill``."""
        req = ent.req
        if ent.resumed:
            req.output.append(nxt)
        else:
            req.output = [nxt]
        self._stamp(req)
        self.stats["tokens"] += 1
        # same finish-at-admit rules as Server's admit + step: max_new
        # reached, instant EOS, or cache exhausted. The last case is
        # resume-only: a fresh prompt is validated to <= max_seq - 1
        # tokens, but a request preempted at slot_pos == max_seq - 1
        # resumes with exactly max_seq tokens — its prefill fills the
        # whole cache and emits the token the interrupted decode step
        # would have been the last to produce, so it must finish HERE
        # (re-entering the decode loop would write past the cache).
        done = len(req.output) >= req.max_new_tokens or (
            req.eos_id is not None and nxt == req.eos_id
        ) or s >= self.max_seq
        if done:
            self._release(slot)
            return
        self.slot_free[slot] = False
        self.slot_pos[slot] = s
        self.slot_req[slot] = req
        self.slot_last_tok[slot] = nxt
        self.slot_orig[slot] = ent.orig
        self.slot_seq[slot] = self._admit_counter
        self._admit_counter += 1

    def _release(self, slot: int):
        """Free a slot's serving state (finish or preempt): token pages go
        back to the pool with their pos rows reset; recurrent state is
        simply dropped (the slot's rows are re-zeroed at the next admit —
        free slots keep decoding padding, so zeroing now would not stick)."""
        freed = self.state.release(slot)
        self._reset_pages(freed)
        if freed:
            self._bt_dirty = True
        self._sync_block_tables()
        self.slot_free[slot] = True
        self.slot_req[slot] = None
        self.slot_orig[slot] = None

    def _preempt(self, slot: int, queue) -> None:
        """Evict a live request; re-queue it at the front for recompute."""
        req = self.slot_req[slot]
        orig = self.slot_orig[slot]
        resume = np.concatenate(
            [orig, np.asarray(req.output, np.int32)]).astype(np.int32)
        self._release(slot)
        queue.appendleft(_Pending(req=req, toks=resume, orig=orig,
                                  resumed=True))
        self.stats["preemptions"] += 1

    def _active_slots(self) -> List[int]:
        return [s for s in range(self.num_slots) if not self.slot_free[s]]

    def _ensure_pages(self, queue):
        """Every live slot gets a page for its next write, preempting the
        most-recently-admitted slot on exhaustion. Terminates: each
        preemption frees >= 1 page (a live slot owns its prefill pages),
        and a slot whose own demand exceeds the pool was rejected at
        validation. Pure-recurrent stacks hold no pages — state slots are
        always writable, so this is a no-op for them. Window-expired pages
        are reclaimed FIRST: freeing dead pages relieves pool pressure
        before any preemption fires."""
        if self.pool is None:
            return
        for slot in self._active_slots():
            dead = self.state.reclaim(slot, int(self.slot_pos[slot]))
            if dead:
                self._reset_pages(dead)
                self._bt_dirty = True
                self.stats["reclaimed_pages"] += len(dead)
        for slot in sorted(self._active_slots(),
                           key=lambda s: self.slot_seq[s]):
            if self.slot_free[slot]:
                continue  # preempted by an earlier iteration
            logical = int(self.slot_pos[slot]) // self.page_size
            if self.pool.has_page(slot, logical):
                continue
            while self.pool.num_free == 0:
                victim = max(self._active_slots(),
                             key=lambda s: self.slot_seq[s])
                self._preempt(victim, queue)
                if victim == slot:
                    break
            if self.slot_free[slot]:
                continue
            self.pool.alloc(slot, logical)
            self._bt_dirty = True
        if self.spec_k >= 2:
            # speculative lookahead: pages for the up-to-k-1 positions a
            # spec round writes past the frontier. BEST-EFFORT, never
            # preempting — a missing lookahead page only caps how many
            # accepted tokens the round may emit (the rest re-derive
            # identically next round), while preempting here would evict
            # live work for tokens that may be rejected anyway. Unused
            # lookahead pages roll back via truncate at round end.
            for slot in sorted(self._active_slots(),
                               key=lambda s: self.slot_seq[s]):
                for i in range(1, self.spec_k):
                    p = int(self.slot_pos[slot]) + i
                    if p >= self.max_seq:
                        break
                    logical = p // self.page_size
                    if self.pool.has_page(slot, logical):
                        continue
                    if self.pool.num_free == 0:
                        break
                    self.pool.alloc(slot, logical)
                    self._bt_dirty = True
        self._sync_block_tables()

    def _emit(self, slot: int, tok: int) -> bool:
        """Record one generated token for ``slot`` (same done rules as
        Server._emit, plus stats and page release); True when finished."""
        req = self.slot_req[slot]
        self.slot_pos[slot] += 1
        req.output.append(tok)
        self._stamp(req)
        self.stats["tokens"] += 1
        done = len(req.output) >= req.max_new_tokens or (
            req.eos_id is not None and tok == req.eos_id
        ) or self.slot_pos[slot] >= self.max_seq
        if done:
            self._release(slot)
        else:
            self.slot_last_tok[slot] = tok
        return done

    def _close_step(self):
        self.stats["steps"] += 1
        if self.pool is not None:
            self.stats["peak_pages_in_use"] = max(
                self.stats["peak_pages_in_use"], self.pool.pages_in_use)
            self.stats["page_util_sum"] += self.pool.utilization

    def _step_all(self):
        if self.spec_k >= 2:
            self._spec_step_all()
        else:
            self._plain_step_all()

    def _plain_step_all(self):
        toks = jnp.asarray(self.slot_last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.slot_pos, jnp.int32)[:, None]
        logits, self.cache = self._decode(self.params, {"tokens": toks},
                                          self.cache, pos)
        logits = np.asarray(logits[:, -1, :])
        for slot in self._active_slots():
            self._emit(slot, self._sample(logits[slot]))
        self._close_step()

    def _spec_step_all(self):
        """One speculative round over the paged cache (DESIGN.md §12).

        On top of Server._spec_step_all's headroom cap, each slot's emit
        count is capped by its CONTIGUOUS page coverage from the frontier:
        a verify write to an unmapped lookahead page drops silently, so
        oracle logits are only trustworthy while every earlier position
        this round was actually written. Tokens accepted beyond coverage
        are discarded and re-derived bitwise next round (greedy decode is
        deterministic from the same prefix). After emitting, pool
        accounting rolls back by block-table truncation: pages wholly
        past each live frontier return to the pool with the usual
        staleness stamp — no page copies.
        """
        from .spec import accept_lengths

        active = self._active_slots()
        k = min([self.spec_k]
                + [self.max_seq - int(self.slot_pos[s]) for s in active])
        if k < 2:
            self._plain_step_all()
            return
        ps = self.page_size
        cover = {}
        for slot in active:
            c = k
            if self.pool is not None:
                c = 0
                for i in range(k):
                    logical = (int(self.slot_pos[slot]) + i) // ps
                    if not self.pool.has_page(slot, logical):
                        break
                    c += 1
            cover[slot] = c  # >= 1: _ensure_pages preempts for page 0
        drafts, self.cache = self.drafter.draft(
            self.params, self.cache, self.slot_last_tok, self.slot_pos,
            k - 1)
        ver_toks = np.concatenate(
            [np.asarray(self.slot_last_tok)[:, None], drafts], axis=1)
        ver_pos = np.asarray(self.slot_pos)[:, None] + np.arange(k)[None, :]
        logits, self.cache = self._decode(
            self.params, {"tokens": jnp.asarray(ver_toks, jnp.int32)},
            self.cache, jnp.asarray(ver_pos, jnp.int32))
        oracle = np.asarray(jnp.argmax(logits, axis=-1))
        acc = accept_lengths(drafts, oracle)
        self.stats["spec_rounds"] += 1
        for slot in active:
            j = min(int(acc[slot]) + 1, cover[slot])
            self.stats["spec_drafted"] += k - 1
            self.stats["spec_accepted"] += j - 1
            for i in range(j):
                if self._emit(slot, int(oracle[slot, i])):
                    break
            if (j < k and not self.slot_free[slot]
                    and int(self.slot_pos[slot]) % ps == 0):
                # a rejection whose accepted frontier lands exactly on a
                # page boundary: the rollback below frees the very page
                # the next decode write needs (re-allocated by
                # _ensure_pages) — counted so tests can force-exercise it
                self.stats["spec_boundary_rejects"] += 1
        for slot in self._active_slots():
            freed = self.state.truncate(slot, int(self.slot_pos[slot]))
            if freed:
                self._reset_pages(freed)
                self._bt_dirty = True
        self._sync_block_tables()
        self._close_step()

    def _admit_from(self, queue):
        """Admit queue-front requests into free slots while pages last."""
        for slot in range(self.num_slots):
            while self.slot_free[slot] and queue:
                head = queue[0]
                if not self.state.admit_ok(len(head.toks)):
                    return  # wait for decode to free pages
                self._admit(queue.popleft(), slot)

    def serve(self, requests: Sequence[Request],
              arrival_steps: Optional[Sequence[int]] = None) -> List[Request]:
        """Run the scheduler until every request finishes.

        ``arrival_steps[i]`` (optional) is the decode-step index at which
        request i becomes visible to the admission queue — pass a Poisson
        trace to replay open-loop traffic; scheduling never changes greedy
        outputs, only wall-clock. All requests are validated up front so a
        bad one leaves the server clean.
        """
        validated = [self._validate(r) for r in requests]
        if arrival_steps is None:
            arrival = [0] * len(requests)
        else:
            if len(arrival_steps) != len(requests):
                raise ValueError("arrival_steps must match requests")
            arrival = [int(a) for a in arrival_steps]
        waiting = collections.deque(sorted(
            ((a, i, _Pending(req=r, toks=t, orig=t))
             for i, (r, t, a) in enumerate(zip(requests, validated, arrival))),
            key=lambda e: (e[0], e[1])))
        queue = collections.deque()
        clock = 0
        while waiting or queue or self._active_slots():
            while waiting and waiting[0][0] <= clock:
                queue.append(waiting.popleft()[2])
            self._admit_from(queue)
            if not self._active_slots():
                # nothing runnable: tick the clock toward the next arrival
                # (an un-admittable queue head with an idle pool cannot
                # happen — lifetime demand was validated against the pool)
                clock += 1
                continue
            # no admission retry here: a preemption frees ceil(pos/ps)
            # pages but the resume needs ceil((pos+1)/ps) and the needy
            # slot just took one, so the queue head can never fit at this
            # point — re-admission happens at the next loop-top _admit_from
            self._ensure_pages(queue)
            if (self._preempt_steps
                    and self.stats["steps"] in self._preempt_steps
                    and self._active_slots()):
                # forced preemption (deterministic test/bench hook): evict
                # the most-recently-admitted slot exactly as pool pressure
                # would — pure-recurrent stacks have no pool to exhaust,
                # so this is the only way to exercise their restore path.
                # Each index fires ONCE: the step counter does not advance
                # when the victim was the only live slot, and re-firing on
                # its resume would preempt forever.
                self._preempt_steps.discard(self.stats["steps"])
                victim = max(self._active_slots(),
                             key=lambda s: self.slot_seq[s])
                self._preempt(victim, queue)
                if not self._active_slots():
                    clock += 1
                    continue
            self._step_all()
            clock += 1
        return list(requests)


def _solve_budget_plan(cfg, params, byte_budget: int):
    """Greedy per-layer (rank, dtype) allocation under a factor-byte budget.

    Scores a small rank grid around the keep_ratio-derived rank per MoE
    layer (core/plan.py::layer_candidates — one barycenter per layer, free
    truncations per rank) and solves the knapsack with solve_plan. Non-MoE
    layers get default recipes.
    """
    import numpy as np

    from ..core.plan import (
        CompressionPlan,
        LayerRecipe,
        layer_candidates,
        solve_plan,
    )
    from ..core.residual import svd_rank_for_ratio
    from ..models import transformer as tfm
    from ..models.model import _EXPERT_KEYS, _unstack_segments

    params = jax.tree_util.tree_map(np.asarray, params)
    specs = tfm.layer_specs(cfg)
    flat = _unstack_segments(params["segments"], tfm.build_plan(cfg))
    moe_idx = [i for i, s in enumerate(specs) if s.ffn == "moe"]
    if not moe_idx:
        raise SystemExit("--byte-budget needs a MoE architecture")
    f = cfg.moe.expert_d_ff
    dd = (3 * cfg.d_model + 2) if cfg.glu else (2 * cfg.d_model + 1)
    r0 = svd_rank_for_ratio(f, dd, cfg.resmoe.keep_ratio)
    ranks = sorted({max(1, r0 // 4), max(1, r0 // 2), r0})
    cands = []
    for i in moe_idx:
        ffn = flat[i]["ffn"]
        bank = {k: ffn[k] for k in _EXPERT_KEYS if k in ffn}
        cands.append(layer_candidates(
            bank, ranks, center="wb",
            barycenter_iters=cfg.resmoe.barycenter_iters,
            ot_solver=cfg.resmoe.ot_solver, seed=i))
    try:
        chosen = solve_plan(cands, byte_budget)
    except ValueError as e:
        raise SystemExit(str(e))
    recipes = [LayerRecipe() for _ in specs]
    for i, c in zip(moe_idx, chosen):
        recipes[i] = c.recipe
    return CompressionPlan(tuple(recipes))


def main():  # pragma: no cover — exercised by examples/serve_compressed.py
    import argparse
    import dataclasses

    from ..configs import reduced_config
    from ..configs.base import ResMoEConfig
    from ..models import build_model

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument(
        "--apply-mode", default=None, choices=ResMoEConfig.APPLY_MODES,
        help="serve a ResMoE-compressed model under this forward path "
             "(default: uncompressed dense experts)",
    )
    ap.add_argument(
        "--token-path-max-tokens", type=int, default=None, metavar="T",
        help="override MoEConfig.token_path_max_tokens: largest token "
             "batch the restore-free modes hand to the ragged per-token "
             "decode path (kernels/resmoe_token.py); 0 keeps every batch "
             "on the dispatched paths",
    )
    ap.add_argument(
        "--mesh", default=None, metavar="DxM",
        help="serve on a (data, model) mesh, e.g. 2x4 — needs that many "
             "devices (CPU: XLA_FLAGS=--xla_force_host_platform_device_count=8); "
             "compressed stores with a restore-free --apply-mode route "
             "through the shard_map expert-parallel layer (DESIGN.md §6)",
    )
    from ..core.quant import STORE_DTYPES

    ap.add_argument(
        "--store-dir", default=None, metavar="DIR",
        help="compress-once/serve-many: boot from the persisted compressed "
             "store in DIR when one exists (no recompression — the "
             "barycenter/SVD pipeline never runs); otherwise compress now "
             "and persist the store there for the next boot. Requires "
             "--apply-mode.",
    )
    ap.add_argument(
        "--store-dtype", default=None, choices=STORE_DTYPES,
        help="serving-store dtype: 'int8' quantizes center/u/v to int8 "
             "with fp32 per-channel scales (~4x fewer factor HBM bytes; "
             "served by the dequant-fused kernels, DESIGN.md §9). "
             "Default: the config's ResMoEConfig.store_dtype (fp32)",
    )
    ap.add_argument(
        "--plan", default=None, metavar="JSON",
        help="per-layer compression plan file (core/plan.py JSON schema, "
             "docs/STORES.md): one recipe per ORIGINAL model layer "
             "overriding rank / store dtype / dropped experts / dropped "
             "blocks. Persisted in the v2 store manifest, so a later "
             "--store-dir boot needs no flags. Requires --apply-mode; "
             "mutually exclusive with --byte-budget and --store-dtype",
    )
    ap.add_argument(
        "--byte-budget", type=int, default=None, metavar="BYTES",
        help="search a per-layer plan (core/plan.py::solve_plan, greedy "
             "error-per-byte) whose factor-store bytes fit BYTES, then "
             "compress and serve under it; the solved plan is persisted "
             "in the v2 store manifest. Requires --apply-mode; mutually "
             "exclusive with --plan and --store-dtype",
    )
    ap.add_argument(
        "--truncate-prompts", action="store_true",
        help="left-truncate prompts longer than max_seq-1 instead of "
             "rejecting them at admit",
    )
    ap.add_argument(
        "--paged", action="store_true",
        help="serve with the continuous-batching scheduler over per-mixer "
             "serving state (ContinuousServer: shared page pool for "
             "attention layers, fixed-size state slots for recurrent "
             "layers, per-step join/leave, preemption with "
             "recompute-restore; DESIGN.md §10–11) instead of the "
             "slot-synchronous row-cache Server — works on every mixer "
             "family, including recurrent (rwkv6) and hybrid "
             "(recurrentgemma) stacks",
    )
    ap.add_argument(
        "--page-size", type=int, default=16, metavar="TOKENS",
        help="tokens per KV page under --paged (default 16)",
    )
    ap.add_argument(
        "--spec-k", type=int, default=0, metavar="K",
        help="barycenter-draft speculative decoding (DESIGN.md §12): each "
             "round drafts K-1 tokens through the center-only path and "
             "verifies them in one full-path forward — greedy outputs are "
             "token-identical to plain decode; 0/1 disables. Requires a "
             "compressed store (--apply-mode)",
    )
    ap.add_argument(
        "--pool-pages", type=int, default=None, metavar="N",
        help="total pages in the shared pool under --paged; undersize it "
             "(below num_slots * max_seq / page_size) to trade preemptions "
             "for HBM — default fully provisions every slot",
    )
    ap.add_argument(
        "--overlapped", action="store_true",
        help="serve through the overlapped engine (launch/engine.py, "
             "DESIGN.md §13): background admission + detokenize threads "
             "around the --paged scheduler, batched prefill-insert with "
             "per-row expert capacity, donated decode state — greedy "
             "outputs stay token-identical to the synchronous servers. "
             "Requires --paged; incompatible with --mesh",
    )
    ap.add_argument(
        "--admit-batch", type=int, default=4, metavar="G",
        help="under --overlapped: rows packed into one batched admission "
             "prefill (smaller groups are padded with dummy rows whose "
             "page-table entries stay unmapped)",
    )
    ap.add_argument(
        "--queue-depth", type=int, default=8, metavar="N",
        help="under --overlapped: bound on the ready queue (prefilled "
             "groups awaiting insertion) and the detokenize queue "
             "(decode steps awaiting readback)",
    )
    from .router import ROUTER_POLICIES

    ap.add_argument(
        "--replicas", type=int, default=1, metavar="N",
        help="serve through a front-door Router over N independent "
             "replica servers (launch/router.py, docs/SERVING.md "
             "multi-host section): each replica owns its page pool, "
             "block tables and slots; the trace is partitioned by "
             "--router-policy and outputs are per-request "
             "token-identical to one server. Requires --paged; with "
             "--mesh each replica gets its own disjoint device mesh",
    )
    ap.add_argument(
        "--router-policy", default="least_loaded", choices=ROUTER_POLICIES,
        help="request->replica assignment under --replicas: "
             "'least_loaded' balances prompt+max-new token cost, "
             "'round_robin' ignores cost; both are deterministic, so "
             "every host of a multi-process deployment derives the same "
             "assignment",
    )
    ap.add_argument(
        "--disaggregate", action="store_true",
        help="prefill/decode disaggregation (launch/router.py): a "
             "dedicated PrefillWorker runs every admission prefill "
             "against its own mini cache and hands the finished request "
             "to the decode server as a block-table row plus page copy "
             "— greedy outputs stay token-identical. Requires --paged; "
             "incompatible with --overlapped",
    )
    args = ap.parse_args()
    if args.overlapped and not args.paged:
        raise SystemExit("--overlapped requires --paged (the engine wraps "
                         "the continuous-batching scheduler)")
    if args.replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    if (args.replicas > 1 or args.disaggregate) and not args.paged:
        raise SystemExit("--replicas > 1 / --disaggregate require --paged "
                         "(replicas and the prefill/decode split are "
                         "built on per-replica page pools)")
    if args.disaggregate and args.overlapped:
        raise SystemExit("--disaggregate is incompatible with "
                         "--overlapped (the engine already owns "
                         "admission on a background thread)")
    cfg = reduced_config(args.arch)
    if args.token_path_max_tokens is not None and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, token_path_max_tokens=args.token_path_max_tokens))
    model = build_model(cfg)
    if args.apply_mode is None and (args.store_dir is not None
                                    or args.store_dtype is not None
                                    or args.plan is not None
                                    or args.byte_budget is not None):
        raise SystemExit("--store-dir/--store-dtype/--plan/--byte-budget "
                         "require --apply-mode (they describe the "
                         "compressed store)")
    if sum(x is not None for x in
           (args.plan, args.byte_budget, args.store_dtype)) > 1:
        raise SystemExit("--plan, --byte-budget and --store-dtype are "
                         "mutually exclusive (a plan names each layer's "
                         "store dtype itself)")
    if args.apply_mode is None:
        params, axes = model.init_split(jax.random.PRNGKey(0))
    else:
        import json

        from ..checkpoint import (
            has_compressed_store,
            load_compressed_store,
            save_compressed_store,
            validate_store_meta,
        )
        from ..core.plan import CompressionPlan
        from ..models import compress_model_params, quantize_compressed_params
        from ..models.model import abstract_compressed_params

        plan = None
        if args.plan is not None:
            with open(args.plan) as fh:
                plan = CompressionPlan.from_json(json.load(fh))
        store_dtype = args.store_dtype or cfg.resmoe.store_dtype
        cfg = dataclasses.replace(
            cfg, resmoe=dataclasses.replace(cfg.resmoe, method="svd",
                                            store_dtype=store_dtype))
        if args.store_dir is not None and has_compressed_store(args.store_dir):
            # store boot: the persisted tree already holds every serving
            # weight — no dense init, no recompression. A v2 manifest's
            # persisted plan wins: it describes the tree on disk.
            params, meta = load_compressed_store(args.store_dir)
            if args.byte_budget is not None:
                raise SystemExit(
                    f"store at {args.store_dir} already exists — "
                    "--byte-budget solves a plan at compress time and "
                    "cannot re-plan a persisted store; re-compress to a "
                    "fresh --store-dir or drop the flag")
            meta_plan = meta.get("plan")
            if meta_plan is not None:
                if plan is not None and plan.to_json() != meta_plan:
                    raise SystemExit(
                        f"store at {args.store_dir} was compressed under a "
                        "different --plan — re-compress to a fresh "
                        "--store-dir or drop the flag (the persisted plan "
                        "boots by itself)")
                plan = CompressionPlan.from_json(meta_plan)
            elif plan is not None:
                raise SystemExit(
                    f"store at {args.store_dir} has no plan but --plan was "
                    "given — re-compress to a fresh --store-dir")
            # uniform-store knobs are meaningful only without a plan (each
            # recipe carries its own rank/dtype); arch always must match
            checks = [("arch", args.arch), ("method", cfg.resmoe.method)]
            if plan is None:
                checks += [("store_dtype", store_dtype),
                           ("keep_ratio", cfg.resmoe.keep_ratio)]
            for key, want in checks:
                if meta.get(key) != want:
                    raise SystemExit(
                        f"store at {args.store_dir} has {key}="
                        f"{meta.get(key)!r}, requested {want!r} — pick a "
                        "different --store-dir or matching flags")
            if plan is not None:
                cfg = dataclasses.replace(
                    cfg, resmoe=dataclasses.replace(cfg.resmoe, plan=plan))
            try:
                validate_store_meta(meta, cfg)
            except ValueError as e:
                raise SystemExit(str(e))
            model = build_model(cfg)
            print(f"booted from persisted store {args.store_dir} "
                  f"({'per-layer plan' if plan is not None else f'dtype={store_dtype}'}; "
                  "no recompression)")
        else:
            model = build_model(cfg)
            params, _ = model.init_split(jax.random.PRNGKey(0))
            if args.byte_budget is not None:
                plan = _solve_budget_plan(cfg, params, args.byte_budget)
                print(f"byte-budget plan ({args.byte_budget} bytes): "
                      + ", ".join(
                          f"L{i}:r{r.rank}/{r.store_dtype}"
                          for i, r in enumerate(plan.recipes)
                          if not r.is_default))
            if plan is not None:
                cfg = dataclasses.replace(
                    cfg, resmoe=dataclasses.replace(cfg.resmoe, plan=plan))
                model = build_model(cfg)
            params, _ = compress_model_params(params, cfg)
            if plan is None and store_dtype == "int8":
                # uniform int8; a plan quantizes per layer during compress
                params = quantize_compressed_params(params)
            if args.store_dir is not None:
                meta = {"arch": args.arch, "store_dtype": store_dtype,
                        "method": cfg.resmoe.method,
                        "keep_ratio": cfg.resmoe.keep_ratio,
                        "num_experts": cfg.moe.num_experts,
                        "d_model": cfg.d_model}
                if plan is not None:
                    meta["plan"] = plan.to_json()
                save_compressed_store(args.store_dir, params, meta=meta)
                print(f"compressed and persisted store -> {args.store_dir}")
        _, axes = abstract_compressed_params(cfg, store_dtype=store_dtype)
    rules = None
    if args.mesh is not None:
        from ..sharding import make_rules
        from .mesh import make_mesh

        try:
            shape = tuple(int(s) for s in args.mesh.lower().split("x"))
        except ValueError:
            shape = ()
        if len(shape) != 2:
            raise SystemExit("--mesh must be DxM, e.g. 2x4")
        rules = make_rules(make_mesh(shape, ("data", "model")))
    routed = args.replicas > 1 or args.disaggregate
    if routed:
        from ..sharding import make_rules as _make_rules
        from .router import Router, build_replicas

        rules_list = None
        if rules is not None:
            if args.replicas > 1:
                # disjoint device groups: replica collectives never
                # share links (sharding.py::split_devices)
                from .mesh import replica_meshes

                rules_list = [_make_rules(m) for m in replica_meshes(
                    args.replicas, shape, ("data", "model"))]
            else:
                rules_list = [rules]
        kw = dict(num_slots=4, max_seq=128, page_size=args.page_size,
                  pool_pages=args.pool_pages, apply_mode=args.apply_mode,
                  truncate_prompts=args.truncate_prompts,
                  spec_k=args.spec_k)
        if args.overlapped:
            kw.update(admit_batch=args.admit_batch,
                      queue_depth=args.queue_depth)
        try:
            replicas = build_replicas(
                model, params, args.replicas,
                disaggregate=args.disaggregate,
                overlapped=args.overlapped, rules_list=rules_list,
                param_axes=axes if rules_list is not None else None,
                **kw)
        except ValueError as e:
            raise SystemExit(str(e))
        server = Router(replicas, policy=args.router_policy)
        print(f"router: {args.replicas} replica(s), "
              f"policy={args.router_policy}, "
              f"disaggregate={args.disaggregate}")
        print(f"serving state: {replicas[0].state.describe()}")
    elif args.overlapped:
        from .engine import OverlappedServer

        server = OverlappedServer(
            model, params, num_slots=4, max_seq=128,
            page_size=args.page_size, pool_pages=args.pool_pages,
            apply_mode=args.apply_mode, rules=rules,
            param_axes=axes if rules is not None else None,
            truncate_prompts=args.truncate_prompts, spec_k=args.spec_k,
            admit_batch=args.admit_batch, queue_depth=args.queue_depth)
        print(f"serving state: {server.state.describe()}")
    elif args.paged:
        server = ContinuousServer(
            model, params, num_slots=4, max_seq=128,
            page_size=args.page_size, pool_pages=args.pool_pages,
            apply_mode=args.apply_mode, rules=rules,
            param_axes=axes if rules is not None else None,
            truncate_prompts=args.truncate_prompts, spec_k=args.spec_k)
        # per-mixer composition up front: what admission will account for
        # (page demand, state slots) before any traffic arrives
        print(f"serving state: {server.state.describe()}")
    else:
        server = Server(model, params, num_slots=4, max_seq=128,
                        apply_mode=args.apply_mode, rules=rules,
                        param_axes=axes if rules is not None else None,
                        truncate_prompts=args.truncate_prompts,
                        spec_k=args.spec_k)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=(8,)),
                max_new_tokens=args.max_new)
        for _ in range(args.requests)
    ]
    server.serve(reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: {r.output}")
    if routed:
        print(f"router stats: {server.aggregate_stats()}")
    elif args.paged:
        print(f"paged stats: {server.stats}")
    elif args.spec_k >= 2:
        print(f"spec stats: {server.spec_stats}")


if __name__ == "__main__":
    main()
