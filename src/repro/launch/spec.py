"""Barycenter-draft speculative decoding (DESIGN.md §12).

The ResMoE store carries its own draft model for free: the shared
Wasserstein-barycenter center WITHOUT the per-expert residuals is a cheap
dense-FFN approximation of every expert. :class:`CenterDrafter` runs k-1
decode steps with ``apply_mode="center_only"`` (models/moe.py) — no u/v
gathers, no capacity dispatch, one dense FFN per MoE layer — and a
verifier scores the chain in ONE multi-token forward through the full
compressed path (the server's existing jitted decode at T=k, riding the
dispatched kernels where the batch clears the token-path gate).

Why greedy verification is bitwise-safe (the acceptance oracle):

  * The verify forward feeds ``[t_last, d_1 .. d_{k-1}]`` at positions
    ``[s .. s+k-1]``; its logits at index i are the full-path next-token
    distribution given the true prefix plus the first i drafts. The
    oracle token ``o_i = argmax(logits[:, i])`` is therefore EXACTLY what
    plain decode would emit after accepting ``d_1 .. d_i`` — so emitting
    the oracle tokens up to (and including) the first draft mismatch
    reproduces plain greedy decode token-for-token, by induction. The
    bonus token ``o_a`` after ``a`` accepted drafts comes free from the
    same forward, so every round emits ``a+1`` in [1, k] tokens.
  * Draft steps write center-only k/v into the live cache, but within
    one multi-token forward the cache update lands BEFORE attention
    (models/attention.py), so the verify pass overwrites all k draft
    positions with full-path k/v before any verify query reads them —
    draft pollution never reaches an emitted logit.
  * Rejected positions keep stale k/v, but a stale entry's stored
    position exceeds every future query position until the frontier
    re-covers it — causally masked — and the round that queries it
    rewrites it first (same update-before-attend ordering). The paged
    cache additionally rolls its POOL ACCOUNTING back by block-table
    truncation (PagePool.truncate_slot — no page copies); freed pages
    get the usual staleness stamp.
  * Greedy argmax consumes no RNG, so the sampler stream is untouched
    and spec_k>0 is a pure latency knob: outputs are token-identical to
    ``spec_k=0`` (pinned by tests/test_serve.py as a parametrization of
    the whole differential matrix).

Spec decoding refuses non-greedy sampling (acceptance would need a
distribution-level rule, not token equality), models without a
compressed center store (nothing to draft with), and recurrent mixers
(O(1) state has no per-position axis to roll back).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tfm
from ..models.model import Model, iter_compressed_stores
from ..sharding import ShardingRules, use_rules

PyTree = Any

# Verifier apply modes pinned by the spec differential matrix (one
# ``# PARITY: spec/<mode>-<dtype>`` test per mode x store dtype) — read by
# scripts/check_parity_matrix.py via ast, keep it a literal tuple. These
# are the two restore-free paths a verify batch can ride: the dispatched
# grouped kernel above the token-path gate, the ragged per-token kernel
# below it.
SPEC_PARITY_MODES = ("fused_kernel", "fused_token")


def validate_spec_model(model: Model, params: PyTree, greedy: bool) -> None:
    """Reject configurations speculative decoding cannot serve correctly.

    Raises ValueError unless: greedy sampling (token-equality acceptance),
    at least one compressed MoE store with a barycenter center (the draft
    model), and no recurrent mixers (their O(1) state advances per token
    and cannot roll back past a rejected draft).
    """
    if not greedy:
        raise ValueError(
            "speculative decoding requires greedy sampling: acceptance "
            "compares draft tokens to the verifier's argmax, which is "
            "only a correct oracle at temperature 0")
    if not any(True for _ in iter_compressed_stores(params)):
        raise ValueError(
            "speculative decoding needs a ResMoE-compressed store — the "
            "shared barycenter center IS the draft model; compress the "
            "params (compress_model_params) before passing spec_k > 0")
    from .paging import RECURRENT_MIXERS

    recurrent = [m for m, _ in tfm.mixer_layout(model.cfg)
                 if m in RECURRENT_MIXERS]
    if recurrent:
        raise ValueError(
            f"speculative decoding cannot serve recurrent mixers "
            f"({sorted(set(recurrent))}): their O(1) state advances on "
            "every drafted token and has no per-position axis to roll "
            "back past a rejection")


def accept_lengths(drafts: np.ndarray, oracle: np.ndarray) -> np.ndarray:
    """Per-slot count of leading draft tokens the oracle confirms.

    ``drafts`` is [B, k-1] (the drafted chain), ``oracle`` [B, k] (the
    verifier's argmax at every position). Returns a [B] int array ``a``
    with ``0 <= a <= k-1``: the round emits ``a+1`` oracle tokens (the
    accepted drafts plus the bonus token after them). A k=1 round has a
    [B, 0] draft matrix and returns zeros — plain decode.
    """
    nd = drafts.shape[1]
    matches = drafts == oracle[:, :nd]
    return np.cumprod(matches, axis=1).sum(axis=1)


class CenterDrafter:
    """k-step greedy drafter over the barycenter center.

    Shares the server's LIVE cache: each draft step writes center-only
    k/v at its position (overwritten by the verify pass before any
    emitted logit reads them) and attends the accepted prefix in place —
    accepted tokens are never recomputed. One jitted [B, 1] decode step,
    compiled once, reused for every draft position.
    """

    def __init__(self, model: Model, rules: Optional[ShardingRules] = None):
        def _under_rules(fn):
            def wrapped(p, b, c, pos):
                with use_rules(rules):
                    return fn(p, b, c, pos)
            return wrapped if rules is not None else fn

        self._step = jax.jit(_under_rules(
            lambda p, b, c, pos: model.decode_step(
                p, b, c, pos, apply_mode="center_only"
            )
        ))

    def step(self, params, batch, cache, positions):
        """One raw center-only decode step (exposed for warmup)."""
        return self._step(params, batch, cache, positions)

    def draft(self, params, cache, last_tokens, start_pos,
              num_drafts: int) -> Tuple[np.ndarray, PyTree]:
        """Greedily draft ``num_drafts`` tokens per slot.

        ``last_tokens`` [B] are the previously emitted tokens (written at
        ``start_pos`` [B] by the first step); returns the [B, num_drafts]
        draft matrix and the cache carrying the draft k/v writes.
        """
        toks = jnp.asarray(np.asarray(last_tokens), jnp.int32)
        pos = np.asarray(start_pos, np.int64)
        drafts = []
        for i in range(num_drafts):
            logits, cache = self._step(
                params, {"tokens": toks[:, None]}, cache,
                jnp.asarray(pos + i, jnp.int32)[:, None])
            toks = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            drafts.append(np.asarray(toks))
        if not drafts:
            b = len(pos)
            return np.zeros((b, 0), np.int64), cache
        return np.stack(drafts, axis=1), cache
