"""Paged KV-cache bookkeeping: a fixed pool of pages + per-slot block tables.

The device-side layout (models/attention.py) is vLLM-style: every attention
layer owns a ``[num_pages, page_size, ...]`` pool shared by all decode
slots, and one ``[num_slots, max_pages_per_slot]`` block table maps each
slot's logical page index (``position // page_size``) to a physical page.
This module is the HOST side of that design: a pure-numpy allocator whose
free-list/owner/block-table state the serving loop mirrors into the device
block tables after every change (ContinuousServer._sync_block_tables).

Invariants (pinned by tests/test_paging.py under hypothesis):
  * conservation — every page is either on the free list or owned by
    exactly one slot; ``num_free + pages_in_use == num_pages`` always.
  * no double assignment — ``alloc`` never hands out a page that is owned
    or already on loan; ``owner`` and the block tables never disagree.
  * table consistency — every ``block_tables[s, l] >= 0`` entry names a
    page whose owner is ``s``; freed slots leave no dangling entries.

Allocation is deliberately trivial (pop from an explicit LIFO free list):
pages are unit-sized and interchangeable, so there is no fragmentation and
no need for anything cleverer. Preemption is just ``free_slot`` — the
scheduler re-queues the victim and restores it later by recompute
(DESIGN.md §10).
"""
from __future__ import annotations

from typing import List

import numpy as np


class PagePool:
    """Fixed pool of ``page_size``-token KV pages shared across slots."""

    def __init__(self, num_pages: int, page_size: int, num_slots: int,
                 max_seq: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError(
                f"PagePool needs positive sizes, got num_pages={num_pages} "
                f"page_size={page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.max_pages_per_slot = -(-int(max_seq) // int(page_size))
        self.block_tables = np.full(
            (self.num_slots, self.max_pages_per_slot), -1, np.int32)
        self.owner = np.full(self.num_pages, -1, np.int32)
        # LIFO: freed pages are reused first (warm reuse under churn)
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))

    # -- queries ----------------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def utilization(self) -> float:
        return self.pages_in_use / self.num_pages

    def pages_needed(self, num_tokens: int) -> int:
        """Pages required to hold ``num_tokens`` cache positions."""
        return -(-int(num_tokens) // self.page_size)

    def owned(self, slot: int) -> List[int]:
        return [int(p) for p in np.flatnonzero(self.owner == slot)]

    def has_page(self, slot: int, logical: int) -> bool:
        return self.block_tables[slot, logical] >= 0

    # -- mutation ---------------------------------------------------------------

    def alloc(self, slot: int, logical: int) -> int:
        """Map ``slot``'s logical page ``logical`` to a fresh physical page."""
        if not (0 <= slot < self.num_slots):
            raise ValueError(f"slot {slot} out of range [0, {self.num_slots})")
        if not (0 <= logical < self.max_pages_per_slot):
            raise ValueError(
                f"logical page {logical} out of range "
                f"[0, {self.max_pages_per_slot}) (max_seq={self.max_seq}, "
                f"page_size={self.page_size})")
        if self.block_tables[slot, logical] >= 0:
            raise RuntimeError(
                f"slot {slot} logical page {logical} already mapped to "
                f"physical page {int(self.block_tables[slot, logical])}")
        if not self._free:
            raise RuntimeError(
                f"page pool exhausted ({self.num_pages} pages all in use) — "
                "caller must preempt before allocating")
        page = self._free.pop()
        assert self.owner[page] == -1, "free-list page had an owner"
        self.owner[page] = slot
        self.block_tables[slot, logical] = page
        return page

    def free_slot(self, slot: int) -> List[int]:
        """Release every page owned by ``slot`` (finish or preempt)."""
        pages = self.owned(slot)
        for p in pages:
            self.owner[p] = -1
            self._free.append(p)
        self.block_tables[slot, :] = -1
        return pages

    # -- self-check (used by the property tests and the soak tier) --------------

    def check(self) -> None:
        """Assert the conservation + consistency invariants."""
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert len(free) + int((self.owner >= 0).sum()) == self.num_pages, (
            "page leak: free + owned != total")
        for p in free:
            assert self.owner[p] == -1, f"page {p} free but owned"
        for s in range(self.num_slots):
            row = self.block_tables[s]
            mapped = row[row >= 0]
            assert len(set(mapped.tolist())) == len(mapped), (
                f"slot {s} maps one physical page twice")
            for p in mapped:
                assert self.owner[p] == s, (
                    f"slot {s} table points at page {int(p)} owned by "
                    f"{int(self.owner[p])}")
        for p in np.flatnonzero(self.owner >= 0):
            s = int(self.owner[p])
            assert p in self.block_tables[s], (
                f"page {int(p)} owned by slot {s} but absent from its table")
