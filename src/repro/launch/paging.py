"""Per-mixer serving-state bookkeeping: page pools, state slots, StatePage.

The device-side layout (models/attention.py) is vLLM-style: every attention
layer owns a ``[num_pages, page_size, ...]`` pool shared by all decode
slots, and one ``[num_slots, max_pages_per_slot]`` block table maps each
slot's logical page index (``position // page_size``) to a physical page.
This module is the HOST side of that design: a pure-numpy allocator whose
free-list/owner/block-table state the serving loop mirrors into the device
block tables after every change (ContinuousServer._sync_block_tables).

Invariants (pinned by tests/test_paging.py under hypothesis):
  * conservation — every page is either on the free list or owned by
    exactly one slot; ``num_free + pages_in_use == num_pages`` always.
  * no double assignment — ``alloc`` never hands out a page that is owned
    or already on loan; ``owner`` and the block tables never disagree.
  * table consistency — every ``block_tables[s, l] >= 0`` entry names a
    page whose owner is ``s``; freed slots leave no dangling entries.

Allocation is deliberately trivial (pop from an explicit LIFO free list):
pages are unit-sized and interchangeable, so there is no fragmentation and
no need for anything cleverer. Preemption is just ``free_slot`` — the
scheduler re-queues the victim and restores it later by recompute
(DESIGN.md §10).

Above the raw pool sits the :class:`StatePage` interface (DESIGN.md §11):
one resource manager per mixer *kind*. Attention mixers keep token pages
(:class:`TokenPages`, wrapping a shared :class:`PagePool` and reclaiming
window-expired pages for sliding-window-only stacks); recurrent mixers
(rglru/rwkv6) keep one fixed-size state slot per serving slot
(:class:`RecurrentSlots` — nothing to page, preemption drops the state and
restores by recompute). :class:`ServingState` composes whichever of the two
a layer plan needs, so hybrid rec/attn stacks hold both and the scheduler
allocates/frees/preempts through one object without knowing the mix.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

# Mixer kinds each StatePage serves (mirrors transformer.MIXER_KINDS; kept
# literal here so the host allocator never imports jax-heavy model code).
ATTENTION_MIXERS = ("gqa", "mla")
RECURRENT_MIXERS = ("rglru", "rwkv")


class PagePool:
    """Fixed pool of ``page_size``-token KV pages shared across slots."""

    def __init__(self, num_pages: int, page_size: int, num_slots: int,
                 max_seq: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError(
                f"PagePool needs positive sizes, got num_pages={num_pages} "
                f"page_size={page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.max_pages_per_slot = -(-int(max_seq) // int(page_size))
        self.block_tables = np.full(
            (self.num_slots, self.max_pages_per_slot), -1, np.int32)
        self.owner = np.full(self.num_pages, -1, np.int32)
        # LIFO: freed pages are reused first (warm reuse under churn)
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))

    # -- queries ----------------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def utilization(self) -> float:
        return self.pages_in_use / self.num_pages

    def pages_needed(self, num_tokens: int) -> int:
        """Pages required to hold ``num_tokens`` cache positions."""
        return -(-int(num_tokens) // self.page_size)

    def owned(self, slot: int) -> List[int]:
        return [int(p) for p in np.flatnonzero(self.owner == slot)]

    def mapped_pages(self, slot: int, num_tokens: int) -> List[int]:
        """Physical pages backing positions [0, num_tokens) in LOGICAL
        order — the copy destination for a batched prefill-insert
        (launch/engine.py): the engine prefills into a private mini pool
        and copies whole pages onto the slot's freshly prepared pages.
        Unlike :meth:`owned` (physical-index order), the result is ordered
        by logical page so source and destination line up."""
        n = self.pages_needed(num_tokens)
        row = self.block_tables[slot, :n]
        if (row < 0).any():
            raise RuntimeError(
                f"slot {slot} has unmapped logical pages in [0, {n}) — "
                "prepare() the slot before asking for its page mapping")
        return [int(p) for p in row]

    def has_page(self, slot: int, logical: int) -> bool:
        return self.block_tables[slot, logical] >= 0

    # -- mutation ---------------------------------------------------------------

    def alloc(self, slot: int, logical: int) -> int:
        """Map ``slot``'s logical page ``logical`` to a fresh physical page."""
        if not (0 <= slot < self.num_slots):
            raise ValueError(f"slot {slot} out of range [0, {self.num_slots})")
        if not (0 <= logical < self.max_pages_per_slot):
            raise ValueError(
                f"logical page {logical} out of range "
                f"[0, {self.max_pages_per_slot}) (max_seq={self.max_seq}, "
                f"page_size={self.page_size})")
        if self.block_tables[slot, logical] >= 0:
            raise RuntimeError(
                f"slot {slot} logical page {logical} already mapped to "
                f"physical page {int(self.block_tables[slot, logical])}")
        if not self._free:
            raise RuntimeError(
                f"page pool exhausted ({self.num_pages} pages all in use) — "
                "caller must preempt before allocating")
        page = self._free.pop()
        assert self.owner[page] == -1, "free-list page had an owner"
        self.owner[page] = slot
        self.block_tables[slot, logical] = page
        return page

    def free_slot(self, slot: int) -> List[int]:
        """Release every page owned by ``slot`` (finish or preempt)."""
        pages = self.owned(slot)
        for p in pages:
            self.owner[p] = -1
            self._free.append(p)
        self.block_tables[slot, :] = -1
        return pages

    def free_page(self, slot: int, logical: int) -> int:
        """Release ONE mapped page (window reclamation), keeping the slot
        live — the block-table entry goes back to -1 so paged_valid masks
        the hole and later writes to it drop on the floor."""
        if not (0 <= slot < self.num_slots):
            raise ValueError(f"slot {slot} out of range [0, {self.num_slots})")
        if not (0 <= logical < self.max_pages_per_slot):
            raise ValueError(
                f"logical page {logical} out of range "
                f"[0, {self.max_pages_per_slot})")
        page = int(self.block_tables[slot, logical])
        if page < 0:
            raise RuntimeError(
                f"slot {slot} logical page {logical} is not mapped — "
                "nothing to reclaim")
        self.owner[page] = -1
        self._free.append(page)
        self.block_tables[slot, logical] = -1
        return page

    def truncate_slot(self, slot: int, keep_pages: int) -> List[int]:
        """Release every page mapped at logical index >= ``keep_pages``,
        keeping the slot live — speculative-decode rollback (DESIGN.md
        §12): a rejected draft's pages unmap by block-table truncation, no
        page copies. The kept prefix is untouched; freed entries go back
        to -1 so paged_valid masks them exactly like a window hole."""
        if not (0 <= slot < self.num_slots):
            raise ValueError(f"slot {slot} out of range [0, {self.num_slots})")
        if keep_pages < 0:
            raise ValueError(f"keep_pages must be >= 0, got {keep_pages}")
        freed = []
        for logical in range(keep_pages, self.max_pages_per_slot):
            if self.block_tables[slot, logical] >= 0:
                freed.append(self.free_page(slot, logical))
        return freed

    # -- self-check (used by the property tests and the soak tier) --------------

    def check(self) -> None:
        """Assert the conservation + consistency invariants."""
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert len(free) + int((self.owner >= 0).sum()) == self.num_pages, (
            "page leak: free + owned != total")
        for p in free:
            assert self.owner[p] == -1, f"page {p} free but owned"
        for s in range(self.num_slots):
            row = self.block_tables[s]
            mapped = row[row >= 0]
            assert len(set(mapped.tolist())) == len(mapped), (
                f"slot {s} maps one physical page twice")
            for p in mapped:
                assert self.owner[p] == s, (
                    f"slot {s} table points at page {int(p)} owned by "
                    f"{int(self.owner[p])}")
        for p in np.flatnonzero(self.owner >= 0):
            s = int(self.owner[p])
            assert p in self.block_tables[s], (
                f"page {int(p)} owned by slot {s} but absent from its table")


# ---------------------------------------------------------------------------
# StatePage: per-mixer serving-state resources (DESIGN.md §11)
# ---------------------------------------------------------------------------


class StatePage:
    """One mixer kind's host-side serving-state resource.

    The scheduler talks to every kind through the same five verbs —
    ``demand`` (units a request of N tokens needs), ``prepare`` (make a
    slot's state writable for its first N tokens), ``release`` (finish or
    preempt), ``reclaim`` (free state no future query can read), and
    ``check`` (invariants). "Units" are kind-specific: token pages for
    attention, state slots for recurrence — :class:`ServingState` keeps
    the accounting separate rather than pretending they convert.
    """

    kind = "abstract"

    def demand(self, num_tokens: int) -> int:
        raise NotImplementedError

    def prepare(self, slot: int, num_tokens: int) -> bool:
        """Make ``slot`` writable for positions [0, num_tokens); returns
        True when the device-visible mapping changed (table resync)."""
        raise NotImplementedError

    def release(self, slot: int) -> List[int]:
        """Free the slot's state; returns released physical pages (token
        kinds) so the server can stamp their staleness sentinels."""
        raise NotImplementedError

    def reclaim(self, slot: int, next_pos: int) -> List[int]:
        """Free state no query at position >= ``next_pos`` can ever read."""
        return []

    def check(self) -> None:
        pass


class TokenPages(StatePage):
    """Attention-mixer state: a shared :class:`PagePool` of KV pages.

    ``window`` is the widest attention window across the stack's attention
    layers — the block tables are shared by every layer, so a page is
    reclaimable only once it is dead in ALL of them. With any global-
    attention layer in the stack ``window`` is the GLOBAL_WINDOW sentinel
    and :meth:`reclaim` never fires (the loop is skipped entirely).
    """

    kind = "token"

    def __init__(self, num_pages: int, page_size: int, num_slots: int,
                 max_seq: int, window: Optional[int] = None):
        self.pool = PagePool(num_pages, page_size, num_slots, max_seq)
        self.window = window
        # a window as wide as the cache can never expire a page
        self.reclaimable = window is not None and window < max_seq

    def demand(self, num_tokens: int) -> int:
        return self.pool.pages_needed(num_tokens)

    def admit_ok(self, num_tokens: int) -> bool:
        return self.pool.num_free >= self.pool.pages_needed(num_tokens)

    def prepare(self, slot: int, num_tokens: int) -> bool:
        changed = False
        for logical in range(self.pool.pages_needed(num_tokens)):
            if not self.pool.has_page(slot, logical):
                self.pool.alloc(slot, logical)
                changed = True
        return changed

    def release(self, slot: int) -> List[int]:
        return self.pool.free_slot(slot)

    def truncate(self, slot: int, num_tokens: int) -> List[int]:
        """Roll a slot back to ``num_tokens`` kept positions: free every
        page wholly past the accepted frontier (speculative rollback)."""
        return self.pool.truncate_slot(slot, self.pool.pages_needed(num_tokens))

    def reclaim(self, slot: int, next_pos: int) -> List[int]:
        """Free pages whose every token is outside the sliding window for
        every query the slot can still issue.

        The mask keeps key ``k`` visible to query ``q`` iff
        ``q - k < window`` (models/attention.py); future queries sit at
        ``q >= next_pos``, so a position is dead once
        ``k <= next_pos - window``, and page ``l`` (last position
        ``(l+1) * page_size - 1``) once that bound covers it whole.
        """
        if not self.reclaimable:
            return []
        ps = self.pool.page_size
        freed = []
        for logical in range(self.pool.max_pages_per_slot):
            if not self.pool.has_page(slot, logical):
                continue
            if (logical + 1) * ps - 1 <= next_pos - self.window:
                freed.append(self.pool.free_page(slot, logical))
        return freed

    def check(self) -> None:
        self.pool.check()


class RecurrentSlots(StatePage):
    """Recurrent-mixer state: one fixed-size slot per serving slot.

    RG-LRU and RWKV6 carry O(1) state per sequence (hidden vector + conv
    taps, or the wkv matrix + token-shift rows) — there is no sequence
    axis to page, so "allocation" is the slot assignment itself and demand
    is always exactly one slot regardless of token count. Preemption keeps
    no state: the resume prefill recomputes it from the token history,
    which is bitwise-identical because the state-carrying prefill scan
    runs the same per-step recurrence as decode (DESIGN.md §11).
    """

    kind = "recurrent"

    def __init__(self, num_slots: int, num_layers: int):
        self.num_slots = int(num_slots)
        self.num_layers = int(num_layers)
        self.occupied = np.zeros(self.num_slots, bool)

    def demand(self, num_tokens: int) -> int:
        return 1

    def prepare(self, slot: int, num_tokens: int) -> bool:
        self.occupied[slot] = True
        return False  # no device-visible mapping to resync

    def release(self, slot: int) -> List[int]:
        self.occupied[slot] = False
        return []

    def check(self) -> None:
        assert self.occupied.shape == (self.num_slots,)


class ServingState:
    """Composite of the StatePages a layer plan needs (DESIGN.md §11).

    Built from ``[(mixer, window), ...]`` in execution order (see
    transformer.mixer_layout): attention layers contribute a shared
    :class:`TokenPages` (ONE pool — the block tables are shared across
    layers, each layer owning its own device-side payload pool), recurrent
    layers a :class:`RecurrentSlots`. A pure-attention stack has
    ``slots is None``, a pure-recurrent stack ``pages is None``, hybrids
    hold both — the scheduler never branches on architecture.
    """

    def __init__(self, mixers: Sequence[Tuple[str, int]], num_slots: int,
                 max_seq: int, page_size: int,
                 pool_pages: Optional[int] = None):
        attn_windows = []
        num_recurrent = 0
        for mixer, window in mixers:
            if mixer in ATTENTION_MIXERS:
                attn_windows.append(int(window))
            elif mixer in RECURRENT_MIXERS:
                num_recurrent += 1
            else:
                raise ValueError(
                    f"unknown mixer kind {mixer!r} — ServingState knows "
                    f"{ATTENTION_MIXERS + RECURRENT_MIXERS}; teach it the "
                    "new kind's state layout before serving it")
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.pages: Optional[TokenPages] = None
        self.slots: Optional[RecurrentSlots] = None
        if attn_windows:
            if pool_pages is None:
                # fully provisioned (never preempts); the interesting
                # deploys pass a smaller pool and lean on preemption
                pool_pages = num_slots * (-(-max_seq // page_size))
            self.pages = TokenPages(pool_pages, page_size, num_slots,
                                    max_seq, window=max(attn_windows))
        if num_recurrent:
            self.slots = RecurrentSlots(num_slots, num_recurrent)
        self.num_attention_layers = len(attn_windows)
        self.num_recurrent_layers = num_recurrent

    @property
    def pool(self) -> Optional[PagePool]:
        return self.pages.pool if self.pages is not None else None

    def members(self) -> List[StatePage]:
        return [m for m in (self.pages, self.slots) if m is not None]

    def demand(self, num_tokens: int) -> dict:
        """Per-kind units a request holding ``num_tokens`` positions needs."""
        return {
            "token_pages": (self.pages.demand(num_tokens)
                            if self.pages is not None else 0),
            "state_slots": (self.slots.demand(num_tokens)
                            if self.slots is not None else 0),
        }

    def validate_demand(self, prompt_tokens: int, total_tokens: int) -> None:
        """Admission check: the request's LIFETIME demand must fit the
        capacity even with every other slot evicted, or the scheduler
        would preempt forever. State slots always fit (demand is one slot
        and the request occupies one); pages can genuinely exceed the
        pool."""
        d = self.demand(total_tokens)
        if self.pages is not None and d["token_pages"] > self.pool.num_pages:
            raise ValueError(
                f"request needs {d['token_pages']} pages + "
                f"{d['state_slots']} state slot(s) "
                f"({prompt_tokens} prompt tokens, {total_tokens} lifetime "
                f"positions at page_size={self.pool.page_size}) but the "
                f"whole pool has {self.pool.num_pages} — raise pool_pages "
                "or shrink the request")

    def admit_ok(self, num_tokens: int) -> bool:
        """Can a fresh admission's prefill be satisfied right now?"""
        if self.pages is not None and not self.pages.admit_ok(num_tokens):
            return False
        return True

    def prepare(self, slot: int, num_tokens: int) -> bool:
        changed = False
        for m in self.members():
            changed |= m.prepare(slot, num_tokens)
        return changed

    def release(self, slot: int) -> List[int]:
        freed: List[int] = []
        for m in self.members():
            freed.extend(m.release(slot))
        return freed

    def reclaim(self, slot: int, next_pos: int) -> List[int]:
        freed: List[int] = []
        for m in self.members():
            freed.extend(m.reclaim(slot, next_pos))
        return freed

    def truncate(self, slot: int, num_tokens: int) -> List[int]:
        """Speculative-decode rollback (launch/spec.py, DESIGN.md §12):
        free the token pages past the accepted frontier. Recurrent state
        has no per-position axis to roll back — spec decoding refuses
        recurrent stacks at construction, so only token pages get here."""
        if self.pages is None:
            return []
        return self.pages.truncate(slot, num_tokens)

    def check(self) -> None:
        for m in self.members():
            m.check()

    def describe(self) -> str:
        parts = []
        if self.pages is not None:
            p = self.pages
            reclaim = (f"window={p.window} reclaim=on" if p.reclaimable
                       else "reclaim=off")
            parts.append(
                f"token_pages({p.pool.num_pages}x{p.pool.page_size} pool, "
                f"{self.num_attention_layers} attn layers, {reclaim})")
        if self.slots is not None:
            parts.append(
                f"recurrent_slots({self.slots.num_slots} slots x "
                f"{self.slots.num_layers} recurrent layers)")
        return " + ".join(parts)
