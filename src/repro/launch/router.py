"""Multi-host disaggregated serving: router + replica set + paged-KV handoff.

Three layers on top of the single-process servers (DESIGN.md §14):

  * :class:`Router` — the front door. It assigns each incoming request to
    one of N replica servers (each a full ``ContinuousServer`` /
    ``OverlappedServer`` with its OWN page pool, block tables and slot
    state — per-replica KV, never shared) and runs every replica's
    serving loop on its own thread. Because each replica is individually
    token-identical to the sync oracle for any schedule it is handed,
    the routed union is per-request token-identical to ONE server
    serving the whole trace — routing is a pure throughput knob, pinned
    by tests/test_router.py.

  * :class:`PrefillWorker` + :class:`DisaggregatedServer` — opt-in
    prefill/decode disaggregation. The worker runs every admission
    prefill against its own single-slot paged mini cache (pages
    ``0..ceil(s/page_size)-1`` via a private block-table row) and hands
    the finished request to the decode server as a **block-table row
    plus page copy**: the decode side allocates pool pages through the
    usual ``ServingState.prepare`` and splices the worker's pages onto
    them in one ``tree_map`` — the same bounded, checkable operation the
    overlapped engine uses for its batched admission
    (engine.py::_copy_rows). Numerics are untouched: the worker runs the
    SAME jitted prefill at the SAME padded length the decode server
    would, and page placement is invisible through block-table
    indirection, so greedy outputs stay token-identical to the oracle.

  * multi-process bring-up — ``python -m repro.launch.router`` is the
    per-host worker entry point: it joins a ``jax.distributed``
    coordination service (launch/mesh.py::init_distributed; CPU CI
    simulates hosts by forcing host-platform devices), derives its host
    index from ``jax.process_index()``, computes the SAME deterministic
    assignment every other host computes, and serves its share of the
    trace. Host-level data parallelism needs no cross-host collectives —
    each replica is self-contained — so the differential test
    (tests/test_multiproc.py, ci.sh multiproc tier) can diff the routed
    union against an in-process oracle token-for-token.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, List, Optional, Sequence

import numpy as np

PyTree = Any

ROUTER_POLICIES = ("least_loaded", "round_robin")


def assign_requests(requests, num_replicas: int,
                    policy: str = "least_loaded") -> List[int]:
    """Deterministic replica index per request (same order as given).

    ``least_loaded`` balances estimated work — prompt tokens plus the
    new-token budget, the request's lifetime cache demand — ties going
    to the lowest replica index; ``round_robin`` ignores cost. Both are
    pure functions of the request list, so every host of a multi-process
    deployment derives the identical assignment with no coordination
    traffic — and assignment can never change a request's tokens, only
    which replica computes them.
    """
    if num_replicas < 1:
        raise ValueError("assign_requests: need at least one replica")
    if policy not in ROUTER_POLICIES:
        raise ValueError(f"unknown routing policy {policy!r}; "
                         f"choose from {ROUTER_POLICIES}")
    if policy == "round_robin":
        return [i % num_replicas for i in range(len(requests))]
    load = [0] * num_replicas
    out = []
    for req in requests:
        cost = int(np.asarray(req.prompt).size) + max(
            int(req.max_new_tokens), 0)
        r = min(range(num_replicas), key=lambda j: (load[j], j))
        load[r] += cost
        out.append(r)
    return out


class Router:
    """Front-door load balancer over a replica set.

    Each replica is a fully independent server (own slots, own page
    pool, own block tables) over shared — read-only — model params.
    ``serve`` partitions the trace by :func:`assign_requests`, replays
    each replica's sub-trace on its own thread (XLA executions release
    the GIL, so replicas genuinely overlap on multicore hosts), and
    re-raises the first replica failure. Outputs are written into the
    caller's ``Request`` objects exactly as a single server would.
    """

    def __init__(self, replicas: Sequence[Any],
                 policy: str = "least_loaded"):
        if not replicas:
            raise ValueError("Router needs at least one replica server")
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"choose from {ROUTER_POLICIES}")
        self.replicas = list(replicas)
        self.policy = policy
        self.stats = {"routed_requests": 0, "routed_batches": 0}

    def assign(self, requests) -> List[int]:
        return assign_requests(requests, len(self.replicas), self.policy)

    def serve(self, requests, arrival_steps: Optional[Sequence[int]] = None):
        """Same contract as ``ContinuousServer.serve``; routed execution.

        ``arrival_steps`` are replica-local: each replica replays its
        assigned requests under their original arrival ticks, which
        preserves the per-replica schedule shape without a shared clock.
        """
        if arrival_steps is not None and len(arrival_steps) != len(requests):
            raise ValueError("arrival_steps must match requests")
        assignment = self.assign(requests)
        n = len(self.replicas)
        buckets: List[list] = [[] for _ in range(n)]
        arrivals: List[list] = [[] for _ in range(n)]
        for i, (req, r) in enumerate(zip(requests, assignment)):
            buckets[r].append(req)
            arrivals[r].append(0 if arrival_steps is None
                               else int(arrival_steps[i]))
        failures: List[Optional[BaseException]] = [None] * n

        def run(j: int):
            try:
                if buckets[j]:
                    self.replicas[j].serve(buckets[j],
                                           arrival_steps=arrivals[j])
            except BaseException as exc:  # noqa: BLE001 — re-raised below
                failures[j] = exc

        threads = [threading.Thread(target=run, args=(j,),
                                    name=f"replica{j}", daemon=True)
                   for j in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for j, exc in enumerate(failures):
            if exc is not None:
                raise RuntimeError(
                    f"replica {j} failed serving "
                    f"{len(buckets[j])} routed requests") from exc
        self.stats["routed_requests"] += len(requests)
        self.stats["routed_batches"] += 1
        return list(requests)

    def aggregate_stats(self) -> dict:
        """Router stats plus summed and per-replica scheduler counters."""
        agg = dict(self.stats)
        agg["replicas"] = len(self.replicas)
        per = []
        for rep in self.replicas:
            st = dict(getattr(rep, "stats", {}))
            per.append(st)
        for key in ("tokens", "steps", "preemptions", "handoffs",
                    "handoff_pages"):
            if any(key in st for st in per):
                agg[key] = sum(int(st.get(key, 0)) for st in per)
        agg["per_replica"] = per
        return agg


@dataclasses.dataclass
class Handoff:
    """One finished prefill, ready for decode-side insertion.

    ``view`` is the worker's mini cache AFTER the prefill (pages
    ``0..n_pages-1`` hold the prompt's KV; recurrent state rows hold the
    post-prompt state), ``logits_last`` the host logits at the true last
    prompt position — the decode server samples from them so the rng
    stream is consumed in the same order as the oracle's admission.
    """
    view: PyTree
    n_pages: int
    logits_last: np.ndarray


class PrefillWorker:
    """Dedicated prefill worker for one :class:`DisaggregatedServer`.

    Owns a single-slot paged cache sized for one ``max_seq`` sequence
    (``ceil(max_seq/page_size)`` private pages) and reuses the decode
    server's jitted prefill — same padded lengths, same apply mode, same
    sharding rules — so the handed-off pages are exactly what an
    in-place admission prefill would have written to the pool.
    """

    def __init__(self, server):
        import jax
        import jax.numpy as jnp  # noqa: F401 — bound below per call

        from ..sharding import split_logical

        self._srv = server
        self.page_size = server.page_size
        self.max_seq = server.max_seq
        self.pages_cap = -(-server.max_seq // server.page_size)
        # pristine template: prefill is functional (no donation), so one
        # fresh-init tree serves every admission — page pos rows start at
        # the staleness sentinel exactly like a freed pool page
        self._template, self._axes = split_logical(
            server.model.init_paged_cache(1, server.max_seq,
                                          server.page_size, self.pages_cap))
        self._treemap = jax.tree_util.tree_map
        self.stats = {"prefills": 0}

    def prefill(self, toks: np.ndarray) -> Handoff:
        import jax.numpy as jnp

        srv = self._srv
        s = len(toks)
        n = -(-s // self.page_size)
        tbl = np.full((1, self.pages_cap), -1, np.int32)
        tbl[0, :n] = np.arange(n, dtype=np.int32)
        tbl_j = jnp.asarray(tbl)

        def upd(leaf, axes):
            if "page_table" not in axes:
                return leaf
            return jnp.broadcast_to(tbl_j, leaf.shape)

        mini = self._treemap(upd, self._template, self._axes,
                             is_leaf=lambda x: hasattr(x, "shape"))
        # identical padding math to ContinuousServer._admit: the jitted
        # prefill sees the same shape set, and the padded tail's writes
        # past page n-1 drop against the unmapped table entries
        s_pad = min(-(-s // srv.prefill_bucket) * srv.prefill_bucket,
                    self.max_seq)
        padded = np.zeros(s_pad, np.int32)
        padded[:s] = toks
        pos = jnp.arange(s_pad, dtype=jnp.int32)[None, :]
        logits, view = srv._prefill(
            srv.params, {"tokens": jnp.asarray(padded)[None, :]}, mini, pos)
        self.stats["prefills"] += 1
        return Handoff(view=view, n_pages=n,
                       logits_last=np.asarray(logits[0, s - 1]))


def _continuous_server_cls():
    from .serve import ContinuousServer

    return ContinuousServer


class DisaggregatedServer(_continuous_server_cls()):
    """Decode-side server of a prefill/decode disaggregated pair.

    Admission never runs a prefill against the pool: the dedicated
    :class:`PrefillWorker` computes the prompt's KV into its own mini
    cache, and ``_admit`` turns the result into pool state as a
    block-table row (``ServingState.prepare`` + table sync, bounded by
    the pool's invariants) plus one page/state-row copy
    (``_insert_handoff``). Preemption resumes take the same path — the
    worker recomputes prompt + generated-so-far, so recompute-restore
    stays token-identical. Stats gain ``handoffs`` / ``handoff_pages``.
    """

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.prefiller = PrefillWorker(self)
        self.stats.update({"handoffs": 0, "handoff_pages": 0})

    def warmup(self, max_len=None):
        """Precompile the worker's prefill shapes + the decode step (the
        inherited warmup would compile pool-shaped prefills this server
        never issues)."""
        import jax.numpy as jnp

        assert all(self.slot_free), "warmup() must run before traffic"
        cap = self.max_seq if max_len is None else min(max_len, self.max_seq)
        shapes = set(range(self.prefill_bucket, cap + 1,
                           self.prefill_bucket))
        shapes.add(cap)
        for s in sorted(shapes):
            self.prefiller.prefill(np.zeros(s, np.int32))
        self.prefiller.stats["prefills"] = 0
        toks = jnp.zeros((self.num_slots, 1), jnp.int32)
        pos = jnp.zeros((self.num_slots, 1), jnp.int32)
        self._decode(self.params, {"tokens": toks}, self.cache, pos)

    def _admit(self, ent, slot: int):
        req = ent.req
        if not ent.resumed and req.max_new_tokens <= 0:
            req.output = []
            return
        toks = ent.toks
        s = len(toks)
        handoff = self.prefiller.prefill(toks)
        # decode-side state: fresh recurrent rows, pool pages for the
        # prompt, table row synced — the same sequence the in-place
        # admission runs, just with the KV arriving by copy
        self._reset_state(slot)
        if self.state.prepare(slot, s):
            self._bt_dirty = True
        self._sync_block_tables()
        self._insert_handoff(slot, s, handoff)
        self._finish_admit(ent, slot, s,
                           self._sample(handoff.logits_last))

    def _insert_handoff(self, slot: int, s: int, handoff: Handoff):
        """Splice the worker's pages onto this slot's pool pages and its
        state rows onto the slot's rows, in one tree_map."""
        import jax
        import jax.numpy as jnp

        dst: List[int] = []
        if self.pool is not None:
            dst = self.pool.mapped_pages(slot, s)
            # the handoff is bounded and checkable: the pool mapped
            # exactly the pages the worker filled, or the copy is wrong
            if len(dst) != handoff.n_pages:
                raise RuntimeError(
                    f"handoff page mismatch: worker filled "
                    f"{handoff.n_pages} pages, pool mapped {len(dst)} "
                    f"for slot {slot} at {s} tokens")
        sp = jnp.arange(len(dst), dtype=jnp.int32) if dst else None
        dp = jnp.asarray(dst, jnp.int32) if dst else None

        def cp(big, small, axes):
            if "page_table" in axes:
                return big  # host-authoritative, synced separately
            if "pages" in axes:
                if sp is None:
                    return big
                ax = axes.index("pages")
                idx = [slice(None)] * big.ndim
                idx[ax] = dp
                return big.at[tuple(idx)].set(jnp.take(small, sp, axis=ax))
            if "batch" in axes:
                ax = axes.index("batch")
                idx = [slice(None)] * big.ndim
                idx[ax] = slice(slot, slot + 1)
                return big.at[tuple(idx)].set(small)
            return big

        self.cache = jax.tree_util.tree_map(
            cp, self.cache, handoff.view, self.cache_axes,
            is_leaf=lambda x: hasattr(x, "shape"))
        self.stats["handoffs"] += 1
        self.stats["handoff_pages"] += len(dst)


def build_replicas(model, params, num_replicas: int, *,
                   disaggregate: bool = False, overlapped: bool = False,
                   rules_list: Optional[Sequence[Any]] = None,
                   param_axes: Optional[PyTree] = None,
                   **server_kwargs) -> List[Any]:
    """Construct ``num_replicas`` independent servers over shared params.

    ``rules_list`` (optional) gives each replica its own sharding rules
    — e.g. one disjoint expert-parallel mesh per replica from
    ``launch/mesh.py::replica_meshes`` — in which case ``param_axes``
    places a copy of the params on that replica's devices.
    """
    if num_replicas < 1:
        raise ValueError("build_replicas: need at least one replica")
    if disaggregate and overlapped:
        raise ValueError(
            "--disaggregate is incompatible with --overlapped: the "
            "engine already owns admission on a background thread; "
            "disaggregation replaces the sync server's in-place prefill")
    if rules_list is not None and len(rules_list) != num_replicas:
        raise ValueError("rules_list must have one entry per replica")
    if disaggregate:
        cls = DisaggregatedServer
    elif overlapped:
        from .engine import OverlappedServer

        cls = OverlappedServer
    else:
        cls = _continuous_server_cls()
    replicas = []
    for i in range(num_replicas):
        kw = dict(server_kwargs)
        if rules_list is not None:
            kw["rules"] = rules_list[i]
            kw["param_axes"] = param_axes
        replicas.append(cls(model, params, **kw))
    return replicas


def main():  # pragma: no cover — exercised by tests/test_multiproc.py
    """Per-host worker of the multi-host replica set.

    Every host runs this entry point with the same trace parameters; the
    deterministic assignment gives each host its disjoint share. CPU CI
    simulates hosts: two of these processes under one coordinator, each
    with forced host-platform devices (scripts/ci.sh multiproc).
    """
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", required=True, metavar="HOST:PORT",
                    help="jax.distributed coordination service address "
                         "(host 0 binds it)")
    ap.add_argument("--num-hosts", type=int, required=True)
    ap.add_argument("--host", type=int, required=True,
                    help="this process's index in [0, num-hosts)")
    ap.add_argument("--simulate-devices", type=int, default=None,
                    metavar="N",
                    help="force N host-platform devices before jax "
                         "initializes (CPU-simulated hosts for CI)")
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="least_loaded",
                    choices=ROUTER_POLICIES)
    ap.add_argument("--disaggregate", action="store_true",
                    help="serve this host's share through the "
                         "prefill/decode disaggregated pair")
    ap.add_argument("--preempt-step", type=int, default=None,
                    help="force a preemption at this decode step "
                         "(differential-test hook)")
    ap.add_argument("--out", required=True, metavar="JSON",
                    help="write {request index: output tokens} here")
    args = ap.parse_args()

    from .mesh import init_distributed

    pid, nprocs = init_distributed(
        coordinator_address=args.coordinator,
        num_processes=args.num_hosts, process_id=args.host,
        simulate_devices=args.simulate_devices)
    assert nprocs == args.num_hosts

    import jax

    from ..configs import reduced_config
    from ..models import build_model
    from .serve import Request

    cfg = reduced_config(args.arch)
    model = build_model(cfg)
    params, _ = model.init_split(jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)
    # the SAME synthetic trace on every host (seeded): assignment then
    # selects this host's disjoint share
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=(8,))
                    .astype(np.int32), max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    assignment = assign_requests(reqs, nprocs, args.policy)
    mine = [i for i, a in enumerate(assignment) if a == pid]
    cls = DisaggregatedServer if args.disaggregate \
        else _continuous_server_cls()
    server = cls(model, params, num_slots=2, max_seq=48, page_size=4,
                 preempt_steps=(None if args.preempt_step is None
                                else [args.preempt_step]))
    server.serve([reqs[i] for i in mine])
    with open(args.out, "w") as fh:
        json.dump({"host": pid, "hosts": nprocs,
                   "local_devices": len(jax.local_devices()),
                   "global_devices": len(jax.devices()),
                   "assignment": assignment,
                   "preemptions": int(server.stats["preemptions"]),
                   "outputs": {str(i): reqs[i].output for i in mine}},
                  fh)
    print(f"host {pid}/{nprocs}: served {len(mine)} of "
          f"{len(reqs)} requests -> {args.out}")


if __name__ == "__main__":
    main()
