"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) data x model single pod, or (2,16,16) pod x data x model."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    from jax.sharding import Mesh

    dev = np.asarray(devices[:need]).reshape(shape)
    return Mesh(dev, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh over the first prod(shape) devices (tests, examples)."""
    import jax
    from jax.sharding import Mesh

    need = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:need]).reshape(shape)
    return Mesh(dev, axes)


def init_distributed(*, coordinator_address: str, num_processes: int,
                     process_id: int,
                     simulate_devices: Optional[int] = None):
    """Join a ``jax.distributed`` coordination service; returns (pid, n).

    Must run before anything initializes jax's backends. When
    ``simulate_devices`` is set, XLA_FLAGS gains
    ``--xla_force_host_platform_device_count=N`` first, so CI can fake N
    accelerators per host on plain CPU — two of these processes under
    one coordinator then look exactly like a 2-host deployment to every
    caller of ``jax.devices()`` / ``jax.process_index()``.
    """
    import os

    if simulate_devices is not None:
        flag = f"--xla_force_host_platform_device_count={simulate_devices}"
        prev = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in prev:
            os.environ["XLA_FLAGS"] = f"{prev} {flag}".strip()
    import jax

    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    return jax.process_index(), jax.process_count()


def local_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Mesh over THIS process's local devices only.

    The per-host replica of a multi-host deployment shards its experts
    across the devices it owns; cross-host traffic is whole requests
    (router assignment), never collectives, so each host's mesh must not
    reference remote devices.
    """
    import jax
    from jax.sharding import Mesh

    need = int(np.prod(shape))
    local = jax.local_devices()
    if len(local) < need:
        raise RuntimeError(
            f"local mesh {shape} needs {need} devices, this host has "
            f"{len(local)}")
    dev = np.asarray(local[:need]).reshape(shape)
    return Mesh(dev, axes)


def replica_meshes(num_replicas: int, shape: Tuple[int, ...],
                   axes: Tuple[str, ...]):
    """One disjoint mesh per replica, carved from the global device list.

    Single-process multi-replica serving (serve.py ``--replicas``) gives
    each replica its own device group so their expert-parallel
    collectives never contend; the split itself lives in
    sharding.py::split_devices.
    """
    from jax.sharding import Mesh

    import jax

    from ..sharding import split_devices

    need = int(np.prod(shape))
    groups = split_devices(jax.devices(), num_replicas, group_size=need)
    return [Mesh(np.asarray(g).reshape(shape), axes) for g in groups]
