"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) data x model single pod, or (2,16,16) pod x data x model."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    from jax.sharding import Mesh

    dev = np.asarray(devices[:need]).reshape(shape)
    return Mesh(dev, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh over the first prod(shape) devices (tests, examples)."""
    import jax
    from jax.sharding import Mesh

    need = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:need]).reshape(shape)
    return Mesh(dev, axes)
