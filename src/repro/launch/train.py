"""Production training driver: pjit train step, microbatching, remat,
checkpoint/restart, straggler logging, optional int8 DP grad compression.

Step construction is pure (``make_train_step``) so the dry-run can lower the
exact production computation; the CLI (``python -m repro.launch.train``)
wires in the data pipeline, checkpointer and supervisor.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import logging
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint import Checkpointer, latest_step
from ..configs import get_config, reduced_config
from ..data import make_pipeline
from ..models import build_model
from ..optim import (
    compress_decompress_allreduce,
    init_grad_compression,
    make_optimizer,
    cosine_warmup_schedule,
)
from ..runtime import StragglerDetector, TrainSupervisor
from ..sharding import make_rules, shardings_from_axes, split_logical, use_rules

log = logging.getLogger("repro.train")

PyTree = Any


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def make_train_step(model, optimizer, microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` splits the global batch on axis 0 and accumulates
    grads with a lax.scan (activation memory / #microbatches).
    """
    cfg = model.cfg

    def loss_of(params, mb):
        return model.loss(params, mb, remat=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch
            )
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches) + x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mb
                )
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g
                )
                return (g_acc, loss_acc + loss), metrics

            (grads, loss_sum), metrics = jax.lax.scan(
                acc, (zero_g, jnp.zeros((), jnp.float32)), mbs
            )
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)

        new_params, new_opt, opt_metrics = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_dp_compressed_train_step(model, optimizer, mesh, dp_axis: str = "data"):
    """Pure-DP training with int8 error-feedback gradient all-reduce.

    Params/opt-state replicated, batch sharded over ``dp_axis``; the grad
    collective is an explicit shard_map psum over quantized payloads
    (DESIGN.md §5). Use on DP-only meshes.
    """
    from ..sharding import shard_map_unchecked

    def step(params, opt_state, comp_state, batch):
        def per_shard(params, comp_err, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, remat=False)[0]
            )(params)
            from ..optim.compression import GradCompressionState

            grads, new_comp = compress_decompress_allreduce(
                grads, GradCompressionState(comp_err), dp_axis
            )
            loss = jax.lax.pmean(loss, dp_axis)
            return grads, new_comp.error, loss

        pspec_rep = jax.tree_util.tree_map(lambda _: P(), params)
        pspec_err = jax.tree_util.tree_map(lambda _: P(), comp_state.error)
        bspec = jax.tree_util.tree_map(lambda _: P(dp_axis), batch)
        grads, new_err, loss = shard_map_unchecked(
            per_shard,
            mesh=mesh,
            in_specs=(pspec_rep, pspec_err, bspec),
            out_specs=(pspec_rep, pspec_err, P()),
        )(params, comp_state.error, batch)
        new_params, new_opt, om = optimizer.update(grads, opt_state, params)
        from ..optim.compression import GradCompressionState

        return new_params, new_opt, GradCompressionState(new_err), {
            "loss": loss, **om
        }

    return step


# ---------------------------------------------------------------------------
# Jit wiring with shardings
# ---------------------------------------------------------------------------


def jit_train_step(model, optimizer, mesh, rules=None, microbatches: int = 1,
                   donate: bool = True):
    """Returns (jitted step, param_shardings, opt_shardings, batch_sharding_fn)."""
    rules = rules or make_rules(mesh)
    abs_params, axes = model.abstract_params()
    param_sh = shardings_from_axes(axes, rules, abs_params)
    abs_opt = jax.eval_shape(optimizer.init, abs_params)
    # opt state: factored stats inherit the param sharding where shapes match
    opt_sh = _opt_shardings(abs_opt, abs_params, param_sh, mesh)

    def batch_shardings(batch_tree):
        return jax.tree_util.tree_map(
            lambda x: NamedSharding(
                mesh, P(tuple(rules.batch_axes) if len(rules.batch_axes) > 1
                        else rules.batch_axes[0])
            ),
            batch_tree,
        )

    step = make_train_step(model, optimizer, microbatches=microbatches)

    def wrapped(params, opt_state, batch):
        with use_rules(rules):
            return step(params, opt_state, batch)

    jitted = jax.jit(
        wrapped,
        in_shardings=(param_sh, opt_sh, None),
        out_shardings=(param_sh, opt_sh, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, param_sh, opt_sh, batch_shardings


def _opt_shardings(abs_opt, abs_params, param_sh, mesh):
    """Match optimizer-state leaves to param shardings by shape; else replicate.

    AdamW m/v mirror params exactly; Adafactor vr/vc are reductions — their
    sharding drops the reduced axis. We re-derive by shape matching against
    the param of the same subtree path prefix.
    """
    import jax.tree_util as jtu

    p_flat = {tuple(str(k) for k in path): (leaf, sh) for (path, leaf), (_, sh) in zip(
        jtu.tree_flatten_with_path(abs_params)[0],
        jtu.tree_flatten_with_path(param_sh)[0],
    )}

    def best(path, leaf):
        keys = tuple(str(k) for k in path)
        # strip optimizer-state prefixes like ['m'] / ['stats'] / suffix 'vr'
        for start in range(len(keys)):
            sub = keys[start:]
            for end in range(len(sub), 0, -1):
                cand = sub[:end]
                if cand in p_flat:
                    pl, sh = p_flat[cand]
                    if tuple(pl.shape) == tuple(leaf.shape):
                        return sh
                    # factored stats: match a reduced shape -> drop last axes
                    if tuple(pl.shape[: len(leaf.shape)]) == tuple(leaf.shape) or \
                       tuple(pl.shape[:-2] + pl.shape[-1:]) == tuple(leaf.shape):
                        spec = sh.spec
                        return NamedSharding(mesh, P(*spec[: len(leaf.shape) - 1], None)
                                             if len(spec) >= len(leaf.shape) else P())
        return NamedSharding(mesh, P())

    flat, treedef = jtu.tree_flatten_with_path(abs_opt)
    return jtu.tree_unflatten(treedef, [best(path, leaf) for path, leaf in flat])


# ---------------------------------------------------------------------------
# CLI driver
# ---------------------------------------------------------------------------


def run_training(
    arch: str,
    steps: int = 300,
    seq_len: int = 256,
    global_batch: int = 8,
    lr: float = 3e-3,
    ckpt_dir: Optional[str] = None,
    checkpoint_every: int = 100,
    reduced: bool = True,
    microbatches: int = 1,
    seed: int = 0,
    log_every: int = 20,
    fail_at: Tuple[int, ...] = (),
) -> Dict[str, Any]:
    cfg = reduced_config(arch) if reduced else get_config(arch)
    model = build_model(cfg)
    params_l = model.init(jax.random.PRNGKey(seed))
    params, _ = split_logical(params_l)
    opt = make_optimizer(cfg.optimizer, cosine_warmup_schedule(lr, 20, steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt, microbatches=microbatches),
                      donate_argnums=(0, 1))
    pipe = make_pipeline(cfg, seq_len, global_batch, seed=seed)
    ckpt = Checkpointer(ckpt_dir, keep=2) if ckpt_dir else None
    losses = []

    from ..runtime import FailureInjector

    injector = FailureInjector(fail_at_steps=fail_at)

    def one_step(step, state):
        params, opt_state = state
        injector.maybe_fail(step)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            l = float(metrics["loss"])
            losses.append((step, l))
            log.info("step %d loss %.4f", step, l)
        return params, opt_state

    def save(step, state):
        if ckpt:
            ckpt.save_async(step, {"params": state[0], "opt": state[1]})

    def restore():
        if not ckpt:
            raise RuntimeError("no checkpoint dir configured")
        ckpt.wait()
        s = latest_step(ckpt.directory)
        if s is None:
            return 0, (params, opt_state)
        tree, _ = ckpt.restore(s, {"params": params, "opt": opt_state})
        return s, (tree["params"], tree["opt"])

    sup = TrainSupervisor(one_step, save, restore, checkpoint_every=checkpoint_every)
    state, final_step = sup.run((params, opt_state), 0, steps)
    if ckpt:
        ckpt.save(final_step, {"params": state[0], "opt": state[1]})
        ckpt.wait()
    return {
        "losses": losses,
        "final_step": final_step,
        "restarts": sup.restarts,
        "params": state[0],
        "straggler_flags": sup.straggler.flagged,
    }


def main():
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true", help="use the full config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    out = run_training(
        args.arch, steps=args.steps, seq_len=args.seq_len, global_batch=args.batch,
        lr=args.lr, reduced=not args.full, ckpt_dir=args.ckpt_dir,
        microbatches=args.microbatches,
    )
    print("final losses:", out["losses"][-3:])


if __name__ == "__main__":
    main()
